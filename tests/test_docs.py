"""Docs lane: markdown links resolve, examples at least compile.

Backs the CI docs job (.github/workflows/ci.yml): documentation is part
of the contract now — README.md / docs/*.md cross-link each other and
point into the source tree, and those pointers must not rot as modules
move. Example *execution* smoke (quickstart) stays in CI only; here we
keep the fast checks so `pytest -x -q` catches a broken link locally.
"""

from __future__ import annotations

import pathlib
import py_compile
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

# every tracked markdown doc: repo root + docs/
MD_FILES = sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))

# [text](target) — markdown inline links, excluding images' alt-text edge
# cases we don't use; reference-style links are not used in this repo.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _links(path: pathlib.Path):
    for target in _LINK_RE.findall(path.read_text()):
        yield target


def test_docs_exist_and_cross_link():
    """README and both design docs exist and link to each other."""
    readme = REPO / "README.md"
    kernels = REPO / "docs" / "kernels.md"
    serving = REPO / "docs" / "serving.md"
    for p in (readme, kernels, serving):
        assert p.exists(), p
    assert any("docs/kernels.md" in t for t in _links(readme))
    assert any("docs/serving.md" in t for t in _links(readme))
    assert any("serving.md" in t for t in _links(kernels))
    assert any("kernels.md" in t for t in _links(serving))


@pytest.mark.parametrize("md", MD_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_markdown_links_resolve(md):
    """Every relative link in every tracked .md points at a real file."""
    broken = []
    for target in _links(md):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure in-page anchor
            continue
        if not (md.parent / path).exists():
            broken.append(target)
    assert not broken, f"{md}: broken links {broken}"


@pytest.mark.parametrize(
    "example",
    sorted((REPO / "examples").glob("*.py")),
    ids=lambda p: p.name,
)
def test_examples_compile(example):
    """Every examples/*.py is at least syntactically valid (the CI docs
    lane additionally executes the quickstart end to end)."""
    py_compile.compile(str(example), doraise=True)
