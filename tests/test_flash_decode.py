"""Flash-decode attention kernel parity (kernels/flash_decode.py).

The streaming Pallas kernel (interpret mode on CPU) against the masked
full-capacity XLA paths in core/kv_cache.py and an explicit fp32 prefix
oracle. Unlike the integer matmul kernels, the contract here is fp32
*reference parity to tight tolerance*, not bit equality — the streaming
merge visits blocks in a different order than the two-tier XLA merge.

Covers the ISSUE 4 parity matrix:
  * mixed-length batches, including length-0 (unadmitted) slots;
  * M = 1 through admission-group batch sizes (b in {1, 2, 5, 8});
  * per-slot block predication at exact S-block boundaries;
  * ring cold-tier layout after wrap-around (SWA, hot_cap = 0);
  * fp8(e4m3) tiers — per-block VMEM dequant vs an f32 oracle over the
    upcast cache (tight) and vs the bf16-computing XLA path (loose);
  * MLA latent path (values = latent prefix of the k-slot, empty v-slot);
  * zero-capacity tiers (SWA hot, max_len <= hot_cap cold) and
    non-dividing / tiny S-blocks;
  * the models/attention.py wiring (attention_decode / mla_decode run the
    same numbers under impl="pallas" and impl="xla");
  * the "decode_attn" row of ops.select_blocks.

Everything runs in Pallas interpret mode on CPU — part of the CI
kernel-parity lane (pytest -m kernel_parity).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as kvc
from repro.kernels import flash_decode as fd
from repro.kernels import ops

pytestmark = pytest.mark.kernel_parity

TOL = dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _build_cache(b, hot, cold, g, d, lens, dtype=jnp.float32, ring=False,
                 seed=0):
    """Cache with per-slot lengths built via active-masked decode appends
    (the continuous-batching write path). Returns (cache, ks, vs) with
    ks/vs the full (b, max_len, g, d) f32 history."""
    cache = kvc.init_cache(b, hot, cold, (g, d), dtype)
    t_max = max(max(lens), 1)
    ks = jax.random.normal(jax.random.PRNGKey(seed), (b, t_max, g, d))
    vs = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t_max, g, d))
    app = kvc.append_decode_ring if ring else kvc.append_decode
    for t in range(max(lens)):
        active = jnp.asarray([t < L for L in lens])
        cache = app(cache, ks[:, t], vs[:, t], active=active)
    return cache, ks, vs


def _cache_prefix(cache, i):
    """Valid (ks, vs) of slot i as stored (tier dtype -> f32): hot prefix
    then cold prefix. Order is irrelevant to attention (permutation
    invariance), which is what makes this the ring oracle too."""
    L = int(cache.lengths[i])
    n_hot = min(L, cache.hot_cap)
    n_cold = min(max(L - cache.hot_cap, 0), cache.cold_cap)
    ks = jnp.concatenate(
        [cache.hot_k[i, :n_hot], cache.cold_k[i, :n_cold]], axis=0
    ).astype(jnp.float32)
    vs = jnp.concatenate(
        [cache.hot_v[i, :n_hot], cache.cold_v[i, :n_cold]], axis=0
    ).astype(jnp.float32)
    return ks, vs


def _oracle_slot(q_i, ks, vs, scale):
    """Plain f32 softmax attention for ONE slot. q_i: (h, d); ks/vs:
    (t, g, d). Returns (h, dv); zeros for an empty prefix."""
    h = q_i.shape[0]
    t, g, d = ks.shape
    if t == 0:
        return np.zeros((h, vs.shape[-1]), np.float32)
    rep = h // g
    qg = np.asarray(q_i, np.float32).reshape(g, rep, d)
    logits = np.einsum("grd,tgd->grt", qg, np.asarray(ks, np.float32)) * scale
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("grt,tgv->grv", p, np.asarray(vs, np.float32))
    return out.reshape(h, vs.shape[-1])


def _oracle(q, cache, scale=None):
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    return np.stack([
        _oracle_slot(q[i], *_cache_prefix(cache, i), scale)
        for i in range(q.shape[0])
    ])


# ---------------------------------------------------------------------------
# GQA: mixed lengths, batch sizes, predication boundaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,lens", [
    (1, [5]),
    (2, [0, 3]),          # length-0 unadmitted slot rides along
    (5, [0, 1, 4, 9, 16]),  # hot-only, boundary, cold, full
    (8, [2, 7, 11, 0, 16, 4, 13, 1]),
])
def test_gqa_mixed_lengths_match_oracle_and_xla(b, lens):
    cache, _, _ = _build_cache(b, 4, 12, 2, 8, lens, seed=b)
    q = jax.random.normal(jax.random.PRNGKey(40 + b), (b, 4, 8))
    got = fd.flash_decode_attention(q, cache, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)
    want = fd.flash_decode_attention(q, cache, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("length", [0, 1, 3, 4, 5, 7, 8, 9, 15, 16])
def test_gqa_every_predication_boundary(length):
    """Lengths at and around every hot/cold S-block edge (hot_cap=4 with
    block_s=4 -> one hot block; cold blocks of 4)."""
    cache, _, _ = _build_cache(1, 4, 12, 1, 8, [length], seed=length)
    q = jax.random.normal(jax.random.PRNGKey(60 + length), (1, 2, 8))
    got = fd.flash_decode_attention(q, cache, impl="pallas", block_s=4)
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)


@pytest.mark.parametrize("block_s", [1, 3, 5, 256])
def test_gqa_non_dividing_blocks(block_s):
    """S-blocks that don't divide the tier capacities (partial last block
    padding is masked before the PV matmul)."""
    cache, _, _ = _build_cache(3, 4, 13, 2, 8, [2, 9, 17], seed=9)
    q = jax.random.normal(jax.random.PRNGKey(77), (3, 4, 8))
    got = fd.flash_decode_attention(q, cache, impl="pallas", block_s=block_s)
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)


def test_gqa_mqa_rep_folding():
    """MQA (g=1, rep=h) and rep=1 (h=g) both fold into the q block."""
    for g, h in ((1, 6), (4, 4)):
        cache, _, _ = _build_cache(2, 4, 12, g, 8, [3, 11], seed=g * 10 + h)
        q = jax.random.normal(jax.random.PRNGKey(g + h), (2, h, 8))
        got = fd.flash_decode_attention(q, cache, impl="pallas")
        np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)


def test_gqa_bf16_q_keeps_out_dtype():
    cache, _, _ = _build_cache(2, 4, 12, 2, 8, [5, 9], dtype=jnp.bfloat16)
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 8), jnp.bfloat16)
    got = fd.flash_decode_attention(q, cache, impl="pallas")
    assert got.dtype == jnp.bfloat16
    want = fd.flash_decode_attention(q, cache, impl="xla")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,  # bf16 output rounding
    )


def test_gqa_zero_cold_cap():
    """max_len <= hot_cap: the cold tier is a zero-capacity dummy."""
    cache, _, _ = _build_cache(2, 8, 0, 2, 8, [3, 8], seed=21)
    q = jax.random.normal(jax.random.PRNGKey(22), (2, 4, 8))
    got = fd.flash_decode_attention(q, cache, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)


# ---------------------------------------------------------------------------
# ring / SWA layout
# ---------------------------------------------------------------------------


def test_ring_after_wrap_matches_oracle():
    """hot_cap=0 ring tier: a wrapped slot attends to the whole window
    (validity clamps at cold_cap), an unwrapped one to its prefix; ring
    storage order doesn't matter (softmax permutation invariance)."""
    cache, _, _ = _build_cache(2, 0, 4, 1, 8, [7, 3], ring=True, seed=31)
    np.testing.assert_array_equal(np.asarray(cache.lengths), [7, 3])
    q = jax.random.normal(jax.random.PRNGKey(32), (2, 2, 8))
    got = fd.flash_decode_attention_ring(q, cache, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)
    want = fd.flash_decode_attention_ring(q, cache, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_ring_exactly_full_window():
    cache, _, _ = _build_cache(1, 0, 6, 2, 8, [6], ring=True, seed=33)
    q = jax.random.normal(jax.random.PRNGKey(34), (1, 4, 8))
    got = fd.flash_decode_attention_ring(q, cache, impl="pallas", block_s=4)
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)


# ---------------------------------------------------------------------------
# fp8 tiers
# ---------------------------------------------------------------------------


def test_fp8_tiers_match_f32_oracle_tight():
    """The kernel upcasts fp8 blocks to f32 in VMEM, so against an f32
    oracle over the (fp8-rounded) cache contents parity is tight; the
    XLA path computes fp8 logits in bf16, so that comparison is loose."""
    cache, _, _ = _build_cache(
        3, 4, 12, 2, 8, [2, 6, 14], dtype=jnp.float8_e4m3fn, seed=41
    )
    q = jax.random.normal(jax.random.PRNGKey(42), (3, 4, 8))
    got = fd.flash_decode_attention(q, cache, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), _oracle(q, cache), **TOL)
    want = fd.flash_decode_attention(q, cache, impl="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2
    )


# ---------------------------------------------------------------------------
# MLA latent path
# ---------------------------------------------------------------------------


def _build_latent_cache(b, hot, cold, dd, lens, seed=0):
    cache = kvc.init_cache(b, hot, cold, (dd,), jnp.float32)
    cache = cache._replace(
        hot_v=jnp.zeros((b, hot, 0)), cold_v=jnp.zeros((b, cold, 0))
    )
    for t in range(max(max(lens), 0)):
        active = jnp.asarray([t < L for L in lens])
        lat = jax.random.normal(jax.random.PRNGKey(seed + t), (b, dd))
        cache = kvc.append_decode(cache, lat, jnp.zeros((b, 0)), active=active)
    return cache


def _latent_oracle(q, cache, value_dim, scale):
    out = []
    for i in range(q.shape[0]):
        ks, _ = _cache_prefix(cache, i)
        t = ks.shape[0]
        if t == 0:
            out.append(np.zeros((q.shape[1], value_dim), np.float32))
            continue
        logits = np.einsum(
            "hd,td->ht", np.asarray(q[i], np.float32), np.asarray(ks)
        ) * scale
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out.append(p @ np.asarray(ks)[:, :value_dim])
    return np.stack(out)


@pytest.mark.parametrize("lens", [[1, 6, 13], [0, 4, 16]])
def test_latent_mixed_lengths(lens):
    b, dd, vdim, scale = 3, 24, 16, 0.17
    cache = _build_latent_cache(b, 4, 12, dd, lens, seed=50)
    q = jax.random.normal(jax.random.PRNGKey(51), (b, 5, dd))
    got = fd.flash_decode_attention_latent(
        q, cache, vdim, scale, impl="pallas"
    )
    np.testing.assert_allclose(
        np.asarray(got), _latent_oracle(q, cache, vdim, scale), **TOL
    )
    want = fd.flash_decode_attention_latent(q, cache, vdim, scale, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_latent_small_blocks():
    cache = _build_latent_cache(2, 3, 9, 24, [2, 11], seed=60)
    q = jax.random.normal(jax.random.PRNGKey(61), (2, 4, 24))
    got = fd.flash_decode_attention_latent(
        q, cache, 16, 0.2, impl="pallas", block_s=2
    )
    np.testing.assert_allclose(
        np.asarray(got), _latent_oracle(q, cache, 16, 0.2), **TOL
    )


# ---------------------------------------------------------------------------
# models/attention wiring: pallas and xla impls agree end to end
# ---------------------------------------------------------------------------


def _impl_cfg(cfg, impl):
    return dataclasses.replace(
        cfg, bitnet=dataclasses.replace(cfg.bitnet, impl=impl)
    )


def test_attention_decode_impl_parity():
    from repro.configs import get_smoke_config
    from repro.models import attention as attn

    cfg = get_smoke_config("falcon3-1b")
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    b = 3
    cache = kvc.init_cache(b, 4, 12, (g, hd), jnp.float32)
    x_hist = jax.random.normal(jax.random.PRNGKey(1), (8, b, cfg.d_model)) * 0.1
    lens = [2, 0, 7]
    outs = {}
    for impl in ("pallas", "xla"):
        c = cache
        for t in range(7):
            active = jnp.asarray([t < L for L in lens])
            _, c = attn.attention_decode(
                p, x_hist[t], _impl_cfg(cfg, impl), "qat", c, active=active
            )
        y, c = attn.attention_decode(
            p, x_hist[7], _impl_cfg(cfg, impl), "qat", c
        )
        outs[impl] = (np.asarray(y), np.asarray(c.lengths))
    np.testing.assert_array_equal(outs["pallas"][1], outs["xla"][1])
    np.testing.assert_allclose(outs["pallas"][0], outs["xla"][0], **TOL)


def test_mla_decode_impl_parity():
    from repro.configs import get_smoke_config
    from repro.models import attention as attn

    cfg = get_smoke_config("deepseek-v3-671b")
    p = attn.init_mla(jax.random.PRNGKey(0), cfg)
    b, dd = 2, cfg.mla.kv_cache_dim
    cache = kvc.init_cache(b, 2, 6, (dd,), jnp.float32)
    cache = cache._replace(
        hot_v=jnp.zeros((b, 2, 0)), cold_v=jnp.zeros((b, 6, 0))
    )
    x_hist = jax.random.normal(jax.random.PRNGKey(1), (5, b, cfg.d_model)) * 0.1
    outs = {}
    for impl in ("pallas", "xla"):
        c = cache
        for t in range(4):
            active = jnp.asarray([True, t < 2])
            _, c = attn.mla_decode(
                p, x_hist[t], _impl_cfg(cfg, impl), "qat", c, active=active
            )
        y, c = attn.mla_decode(p, x_hist[4], _impl_cfg(cfg, impl), "qat", c)
        outs[impl] = (np.asarray(y), np.asarray(c.lengths))
    np.testing.assert_array_equal(outs["pallas"][1], outs["xla"][1])
    np.testing.assert_allclose(outs["pallas"][0], outs["xla"][0],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused-RoPE decode form: unrotated q/k_new in, pending token in-stream,
# rotated k out; cache is the PRE-append state (ISSUE 5 decode satellite)
# ---------------------------------------------------------------------------


def _fused_parity(b, hot, cold, g, h, d, lens, ring, active=None,
                  block_s=None, seed=0):
    cache, _, _ = _build_cache(b, hot, cold, g, d, lens, ring=ring, seed=seed)
    ks = jax.random.split(jax.random.PRNGKey(seed + 999), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kn = jax.random.normal(ks[1], (b, g, d))
    vn = jax.random.normal(ks[2], (b, g, d))
    act = None if active is None else jnp.asarray(active)
    entry = fd.flash_decode_attention_ring if ring else fd.flash_decode_attention
    op, kp = entry(q, cache, impl="pallas", k_new=kn, v_new=vn, active=act,
                   rope_theta=1e4, block_s=block_s)
    ox, kx = entry(q, cache, impl="xla", k_new=kn, v_new=vn, active=act,
                   rope_theta=1e4, block_s=block_s)
    # rotated-k parity is ulp-level (kernel rope vs apply_rope fuse
    # differently under XLA); attention parity at the usual fp32 TOL
    np.testing.assert_allclose(np.asarray(kp), np.asarray(kx),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(op), np.asarray(ox), **TOL)


@pytest.mark.parametrize("lens", [[0, 5, 14], [3, 4, 15], [1, 8, 12]])
def test_fused_rope_linear_mixed_lengths(lens):
    # lens stay < capacity: the pre-append form appends one token, and a
    # full cache is out of contract (the engine's max_len bound)
    _fused_parity(3, 4, 12, 2, 4, 8, lens, ring=False, seed=sum(lens))


def test_fused_rope_active_mask_gates_pending_token():
    """Inactive slots attend only their old prefix — the pending (k, v)
    joins the stream for active slots alone."""
    _fused_parity(3, 4, 12, 2, 4, 8, [2, 7, 11], ring=False,
                  active=[True, False, True])


@pytest.mark.parametrize("lens", [[9, 3], [6, 12], [5, 6]])
def test_fused_rope_ring_masks_evictee(lens):
    """Wrapped ring: the slot the upcoming append will overwrite holds
    position len - w — outside the decode token's window — and must be
    masked; unwrapped slots keep their whole prefix."""
    _fused_parity(2, 0, 6, 2, 4, 8, lens, ring=True, block_s=2,
                  seed=sum(lens))


def test_fused_rope_ring_inactive_slot_keeps_evictee():
    """An inactive slot appends nothing, so nothing is evicted: its old
    wrapped window stays fully valid (matching the XLA reference)."""
    _fused_parity(2, 0, 6, 2, 4, 8, [12, 8], ring=True,
                  active=[False, True], block_s=2)


def test_attention_decode_fused_vs_xla_path():
    """models/attention.attention_decode: the Pallas fused-RoPE path and
    the legacy rotate->append->read XLA path produce the same outputs and
    (to rope ulp) the same caches over a multi-step mixed-length run."""
    from repro.configs import get_smoke_config
    from repro.models import attention as attn

    cfg = get_smoke_config("falcon3-1b")
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    b = 3
    cache = kvc.init_cache(b, 4, 12, (g, hd), jnp.float32)
    x_hist = jax.random.normal(jax.random.PRNGKey(1), (8, b, cfg.d_model)) * 0.1
    lens = [2, 0, 7]
    outs = {}
    for impl in ("pallas", "xla"):
        c = cache
        for t in range(7):
            active = jnp.asarray([t < L for L in lens])
            _, c = attn.attention_decode(
                p, x_hist[t], _impl_cfg(cfg, impl), "qat", c, active=active
            )
        y, c = attn.attention_decode(p, x_hist[7], _impl_cfg(cfg, impl), "qat", c)
        outs[impl] = (np.asarray(y), c)
    np.testing.assert_array_equal(
        np.asarray(outs["pallas"][1].lengths), np.asarray(outs["xla"][1].lengths))
    np.testing.assert_allclose(outs["pallas"][0], outs["xla"][0],
                               rtol=5e-5, atol=5e-5)
    for a, bb in zip(jax.tree.leaves(outs["pallas"][1]),
                     jax.tree.leaves(outs["xla"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block table
# ---------------------------------------------------------------------------


def test_select_blocks_decode_attn_kind():
    # GQA rep row: S-block 256, capped at capacity
    assert ops.select_blocks(4, 128, 544, "pack2", kind="decode_attn") == (
        16, 128, 256)
    assert ops.select_blocks(1, 64, 96, "pack2", kind="decode_attn") == (
        16, 128, 96)
    # MLA row (many q heads): narrower S-block; lane cap at round_up(n, 128)
    assert ops.select_blocks(64, 576, 4096, "pack2", kind="decode_attn") == (
        128, 128, 128)
    # codec is ignored for this kind (no packed operand)
    assert ops.select_blocks(4, 128, 544, "pack243", kind="decode_attn") == (
        16, 128, 256)
