"""Pallas ternary-matmul kernel vs pure-jnp oracle (interpret mode on CPU).

Sweeps shapes, codecs and block sizes; all comparisons are exact integer
equality (the kernel is integer-only by construction).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core.ternary import act_quant, weight_quant_absmean
from repro.kernels import ops, ref

pytestmark = pytest.mark.kernel_parity


def _random_case(seed, m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    xq = jax.random.randint(kx, (m, k), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(kw, (k, n), -1, 2, dtype=jnp.int8)
    return xq, wq


@pytest.mark.parametrize("codec", ["pack2", "pack243"])
@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 64, 16),       # tiny
        (1, 256, 128),     # GEMV (decode shape)
        (16, 512, 256),    # one full default block
        (32, 520, 96),     # K not multiple of block/group
        (5, 33, 7),        # everything ragged
    ],
)
def test_pallas_matches_ref(codec, m, k, n):
    xq, wq = _random_case(m * 7919 + k * 31 + n, m, k, n)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    packed = pack(wq)
    got = ops.ternary_matmul(
        xq, packed, k=k, codec=codec, impl="pallas",
        block_m=8, block_n=128, block_k=20 if codec == "pack243" else 16,
    )
    want = ref.ternary_matmul_ref(xq, packed, k=k, codec=codec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and both equal the plain integer matmul
    np.testing.assert_array_equal(
        np.asarray(want, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


@pytest.mark.parametrize("codec", ["pack2", "pack243"])
def test_xla_path_matches_ref(codec):
    xq, wq = _random_case(0, 12, 300, 48)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    packed = pack(wq)
    got = ops.ternary_matmul(xq, packed, k=300, codec=codec, impl="xla")
    want = ref.ternary_matmul_ref(xq, packed, k=300, codec=codec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_leading_dims():
    xq = jax.random.randint(jax.random.PRNGKey(1), (2, 3, 64), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(2), (64, 32), -1, 2, dtype=jnp.int8)
    packed = packing.pack2(wq)
    got = ops.ternary_matmul(
        xq, packed, k=64, codec="pack2", impl="pallas", block_m=8, block_n=32, block_k=16
    )
    want = jnp.einsum("btk,kn->btn", xq.astype(jnp.int32), wq.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 17),
    k=st.integers(1, 130),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**30),
    codec=st.sampled_from(["pack2", "pack243"]),
)
def test_property_kernel_exact(m, k, n, seed, codec):
    xq, wq = _random_case(seed, m, k, n)
    pack = packing.pack2 if codec == "pack2" else packing.pack243
    got = ops.ternary_matmul(
        xq, pack(wq), k=k, codec=codec, impl="pallas",
        block_m=8, block_n=32, block_k=20,
    )
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


def test_int8_accumulator_headroom():
    """Paper: 8-bit TriMLA output suffices for symmetric ternary weights.
    We use int32 accumulators (TPU-native); verify no overflow at LLM dims."""
    m, k, n = 4, 8192, 64
    xq = jnp.full((m, k), 127, dtype=jnp.int8)
    wq = jnp.ones((k, n), dtype=jnp.int8)  # worst case: all +1
    got = ops.ternary_matmul(xq, packing.pack2(wq), k=k, codec="pack2", impl="xla")
    assert int(got.max()) == 127 * k  # exact, no wraparound
    assert 127 * k < 2**31 - 1


def test_bitlinear_packed_vs_qat_consistency():
    """Packed inference forward must match the dequantized reference within
    float tolerance (scales applied outside the integer kernel)."""
    from repro.core import bitlinear

    key = jax.random.PRNGKey(3)
    params = bitlinear.init(key, 96, 48)
    x = jax.random.normal(jax.random.PRNGKey(4), (6, 96))
    pw = bitlinear.quantize_pack(params, codec="pack2")
    y_packed = bitlinear.apply_packed(pw, x, impl="xla")
    y_ref = ref.bitlinear_ref(x, params["w"])
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_bitlinear_pallas_impl_matches_xla():
    from repro.core import bitlinear

    params = bitlinear.init(jax.random.PRNGKey(5), 128, 64)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 128))
    pw = bitlinear.quantize_pack(params, codec="pack243")
    y_xla = bitlinear.apply_packed(pw, x, impl="xla")
    y_pal = bitlinear.apply_packed(pw, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitlinear_dtypes(dtype):
    from repro.core import bitlinear

    params = bitlinear.init(jax.random.PRNGKey(7), 64, 32, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 64), dtype=dtype)
    y = bitlinear.apply_qat(params, x)
    assert y.dtype == dtype and y.shape == (2, 32)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
