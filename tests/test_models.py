"""Per-architecture smoke tests + cross-path consistency tests.

For every assigned arch (reduced same-family config): one forward and one
train-gradient step on CPU asserting output shapes and no NaNs, plus
decode-vs-forward teacher-forcing consistency (validates blockwise
attention, the tiered DR cache, MLA absorption and the SSD recurrence
against the full-sequence path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_configs
from repro.models import transformer as T

ARCHS = list(list_configs())


def _batch_for(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (b, s, cfg.frontend_dim)) * 0.3,
            "labels": jnp.zeros((b, s), jnp.int32),
        }
    if cfg.family == "vlm":
        st = s - cfg.n_patches
        return {
            "tokens": jax.random.randint(key, (b, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(key, (b, cfg.n_patches, cfg.frontend_dim)) * 0.3,
            "labels": jnp.zeros((b, st), jnp.int32),
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = T.forward(params, cfg, batch, mode="qat", remat=False)
    b = batch.get("tokens", batch.get("frames")).shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_grad_step(arch):
    """One QAT train step: CE loss, grads finite, params update."""
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)

    def loss_fn(p):
        logits, aux = T.forward(p, cfg, batch, mode="qat", remat=True)
        labels = batch["labels"]
        tgt = logits[:, -labels.shape[1] :, :]
        ce = -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(tgt, axis=-1), labels[..., None], axis=-1
            )
        )
        return ce + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    # grads reach the embedding (end-to-end connectivity)
    gmax = max(float(jnp.abs(g).max()) for g in leaves)
    assert gmax > 0, arch
    # sgd step keeps everything finite
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))


DECODER_ARCHS = [a for a in ARCHS if get_smoke_config(a).has_decode]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits must match full-forward logits.

    Exercises: blockwise attention == tiered-cache attention, MLA absorbed
    == non-absorbed, SSD chunked scan == recurrence, ring buffer == SWA
    masking, MoE determinism.
    """
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    batch = _batch_for(cfg, b=b, s=s, seed=7)
    logits_full, _ = T.forward(params, cfg, batch, mode="qat", remat=False)

    p_len = 10 if cfg.family != "vlm" else 4  # prefill text length
    if cfg.family == "vlm":
        pre = {"tokens": batch["tokens"][:, :p_len], "patches": batch["patches"]}
        n_text = batch["tokens"].shape[1]
        full_prefill_len = cfg.n_patches + p_len
    else:
        pre = {"tokens": batch["tokens"][:, :p_len]}
        n_text = s
        full_prefill_len = p_len

    logits_pre, cache = T.prefill(params, cfg, pre, hot_cap=4, max_len=s + 8, mode="qat")
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_full[:, full_prefill_len - 1]),
        rtol=2e-3,
        atol=2e-3,
    )

    for t in range(p_len, n_text):
        tok = batch["tokens"][:, t]
        logits_t, cache = T.decode_step(params, cfg, tok, cache, mode="qat")
        want = logits_full[:, (cfg.n_patches if cfg.family == "vlm" else 0) + t]
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(want), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} step {t}",
        )


def test_exact_param_counts_match_models():
    """ModelConfig.param_count() equals the real initialized tree size."""
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        if cfg.bitnet.lora_rank:
            continue  # param_count() counts the frozen base only
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        n_cfg = cfg.param_count()
        # conv/ssm scalars and norm variants allowed ±2% slack
        assert abs(n_real - n_cfg) / n_real < 0.02, (arch, n_real, n_cfg)


def test_full_config_param_counts_sane():
    """Full configs land near their nameplate sizes."""
    from repro.configs import get_config

    expect = {
        "qwen3-8b": (7.0e9, 9.5e9),
        "qwen3-32b": (30e9, 35e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "mixtral-8x22b": (135e9, 145e9),
        "deepseek-v3-671b": (640e9, 700e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "zamba2-7b": (6.0e9, 9.0e9),
        "llava-next-34b": (32e9, 36e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
        "falcon3-1b": (1.4e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")
