"""Per-architecture smoke tests + cross-path consistency tests.

For every assigned arch (reduced same-family config): one forward and one
train-gradient step on CPU asserting output shapes and no NaNs, plus
decode-vs-forward teacher-forcing consistency (validates blockwise
attention, the tiered DR cache, MLA absorption and the SSD recurrence
against the full-sequence path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_configs
from repro.models import transformer as T

ARCHS = list(list_configs())


def _batch_for(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (b, s, cfg.frontend_dim)) * 0.3,
            "labels": jnp.zeros((b, s), jnp.int32),
        }
    if cfg.family == "vlm":
        st = s - cfg.n_patches
        return {
            "tokens": jax.random.randint(key, (b, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(key, (b, cfg.n_patches, cfg.frontend_dim)) * 0.3,
            "labels": jnp.zeros((b, st), jnp.int32),
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = T.forward(params, cfg, batch, mode="qat", remat=False)
    b = batch.get("tokens", batch.get("frames")).shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_grad_step(arch):
    """One QAT train step: CE loss, grads finite, params update."""
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)

    def loss_fn(p):
        logits, aux = T.forward(p, cfg, batch, mode="qat", remat=True)
        labels = batch["labels"]
        tgt = logits[:, -labels.shape[1] :, :]
        ce = -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(tgt, axis=-1), labels[..., None], axis=-1
            )
        )
        return ce + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    # grads reach the embedding (end-to-end connectivity)
    gmax = max(float(jnp.abs(g).max()) for g in leaves)
    assert gmax > 0, arch
    # sgd step keeps everything finite
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))


DECODER_ARCHS = [a for a in ARCHS if get_smoke_config(a).has_decode]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits must match full-forward logits.

    Exercises: blockwise attention == tiered-cache attention, MLA absorbed
    == non-absorbed, SSD chunked scan == recurrence, ring buffer == SWA
    masking, MoE determinism.
    """
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    batch = _batch_for(cfg, b=b, s=s, seed=7)
    logits_full, _ = T.forward(params, cfg, batch, mode="qat", remat=False)

    p_len = 10 if cfg.family != "vlm" else 4  # prefill text length
    if cfg.family == "vlm":
        pre = {"tokens": batch["tokens"][:, :p_len], "patches": batch["patches"]}
        n_text = batch["tokens"].shape[1]
        full_prefill_len = cfg.n_patches + p_len
    else:
        pre = {"tokens": batch["tokens"][:, :p_len]}
        n_text = s
        full_prefill_len = p_len

    logits_pre, cache = T.prefill(params, cfg, pre, hot_cap=4, max_len=s + 8, mode="qat")
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_full[:, full_prefill_len - 1]),
        rtol=2e-3,
        atol=2e-3,
    )

    for t in range(p_len, n_text):
        tok = batch["tokens"][:, t]
        logits_t, cache = T.decode_step(params, cfg, tok, cache, mode="qat")
        want = logits_full[:, (cfg.n_patches if cfg.family == "vlm" else 0) + t]
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(want), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} step {t}",
        )


def test_exact_param_counts_match_models():
    """ModelConfig.param_count() equals the real initialized tree size."""
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        if cfg.bitnet.lora_rank:
            continue  # param_count() counts the frozen base only
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        n_cfg = cfg.param_count()
        # conv/ssm scalars and norm variants allowed ±2% slack
        assert abs(n_real - n_cfg) / n_real < 0.02, (arch, n_real, n_cfg)


def test_full_config_param_counts_sane():
    """Full configs land near their nameplate sizes."""
    from repro.configs import get_config

    expect = {
        "qwen3-8b": (7.0e9, 9.5e9),
        "qwen3-32b": (30e9, 35e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "mixtral-8x22b": (135e9, 145e9),
        "deepseek-v3-671b": (640e9, 700e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "zamba2-7b": (6.0e9, 9.0e9),
        "llava-next-34b": (32e9, 36e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
        "falcon3-1b": (1.4e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")


# ---------------------------------------------------------------------------
# blockwise-attention chunking: non-dividing lengths pad + mask instead of
# collapsing to 1-token chunks (ISSUE 5 satellite regression)
# ---------------------------------------------------------------------------


def test_blockwise_chunk_no_degenerate_halving():
    """An odd length past the target (e.g. 1025) used to halve the chunk
    all the way down to 1, turning the scan into a length-S loop of
    1-token blocks; now the chunk stays at the target and the remainder
    is padded + masked."""
    from repro.models.attention import DEFAULT_CHUNK, _chunk

    assert _chunk(1025) == DEFAULT_CHUNK  # was 1 (1025 halves to 1)
    assert _chunk(513) == DEFAULT_CHUNK  # was 1
    assert _chunk(257) == 257  # short sequences still use one chunk
    assert _chunk(512) == DEFAULT_CHUNK


@pytest.mark.parametrize("sq,sk", [(257, 257), (13, 7), (96, 33)])
def test_blockwise_padded_lengths_match_dense_reference(sq, sk):
    """Padded+masked blockwise attention == dense softmax attention for
    non-dividing (prime/odd) sequence lengths, causal and windowed."""
    from repro.models.attention import blockwise_attention

    b, g, r, d = 1, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(sq + sk), 3)
    q = jax.random.normal(ks[0], (b, g, r, sq, d))
    k = jax.random.normal(ks[1], (b, g, sk, d))
    v = jax.random.normal(ks[2], (b, g, sk, d))

    def dense(window):
        qf = np.asarray(q, np.float64)
        kf = np.asarray(k, np.float64)
        vf = np.asarray(v, np.float64)
        logits = np.einsum("bgrqd,bgkd->bgrqk", qf, kf) * d**-0.5
        q_pos = np.arange(sq)[:, None]
        k_pos = np.arange(sk)[None]
        mask = q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        logits = np.where(mask, logits, -1e30)
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p = np.where(mask, p, 0.0)
        denom = np.maximum(p.sum(-1, keepdims=True), 1e-30)
        return np.einsum("bgrqk,bgkd->bgrqd", p / denom, vf)

    for window in (0, 5):
        got = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(
            np.asarray(got), dense(window), rtol=2e-5, atol=2e-5,
            err_msg=f"window={window}",
        )


# ---------------------------------------------------------------------------
# decode-headroom knob (ISSUE 5 satellite): the historical hard-wired
# `max_len = s + 128` is now cfg.decode_headroom / a prefill argument
# ---------------------------------------------------------------------------


def test_prefill_decode_headroom_knob():
    import dataclasses

    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size)

    def capacity(cache):
        c = cache["attn"]
        return c.hot_k.shape[2] + c.cold_k.shape[2]

    # default: prompt + cfg.decode_headroom (the historical 128)
    _, cache = T.prefill(params, cfg, {"tokens": toks}, mode="qat")
    assert capacity(cache) == 6 + cfg.decode_headroom == 6 + 128
    # per-call override
    _, cache = T.prefill(params, cfg, {"tokens": toks}, mode="qat", headroom=4)
    assert capacity(cache) == 10
    # config knob
    cfg16 = dataclasses.replace(cfg, decode_headroom=16)
    _, cache = T.prefill(params, cfg16, {"tokens": toks}, mode="qat")
    assert capacity(cache) == 22
    # explicit max_len still wins over everything
    _, cache = T.prefill(params, cfg16, {"tokens": toks}, mode="qat",
                         max_len=40, headroom=4)
    assert capacity(cache) == 40
    # the headroom really is the decode budget: token 10 must still fit
    _, cache = T.prefill(params, cfg, {"tokens": toks}, mode="qat", headroom=4)
    tok = jnp.zeros((1,), jnp.int32)
    for _ in range(4):
        _, cache = T.decode_step(params, cfg, tok, cache, mode="qat")
    assert int(cache["attn"].lengths[0, 0]) == 10  # exactly at capacity
