"""Fault tolerance: crash-recovery determinism, stragglers, preemption,
checkpoint atomicity/integrity/elasticity."""

import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import get_smoke_config
from repro.distributed.fault import (
    FaultInjector,
    InjectedFault,
    PreemptionGuard,
    StragglerMonitor,
    run_with_recovery,
)
from repro.training import loop as train_loop
from repro.training.optimizer import AdamWConfig

CFG = get_smoke_config("falcon3-1b")
OPT = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)


def _run(steps, ckpt_dir=None, fault=None, preemption=None, seed=0):
    return train_loop.train(
        CFG, steps=steps, global_batch=4, seq_len=16, opt_cfg=OPT,
        ckpt_dir=ckpt_dir, ckpt_every=5, seed=seed, verbose=False,
        fault=fault, preemption=preemption,
    )


def test_crash_recovery_bitwise_identical(tmp_path):
    """Crash at step 12, auto-resume from step 10 => same final params as an
    uninterrupted run (data-pipeline state rides in the checkpoint)."""
    ref = _run(20)

    d = str(tmp_path / "ck")
    fault = FaultInjector(fail_at_step=12)

    def attempt(_resume):
        return _run(20, ckpt_dir=d, fault=fault)

    result = run_with_recovery(attempt, max_restarts=2)
    assert fault.fired
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(result["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_injected_fault_raises_without_recovery(tmp_path):
    with pytest.raises(InjectedFault):
        _run(20, ckpt_dir=str(tmp_path / "ck2"), fault=FaultInjector(fail_at_step=3))


def test_preemption_checkpoints_cleanly(tmp_path):
    d = str(tmp_path / "ck3")
    guard = PreemptionGuard()

    # preempt after a few steps via the fault hook calling request()
    class PreemptAt(FaultInjector):
        def check(self, step):
            if step == 7:
                guard.request()

    r = _run(20, ckpt_dir=d, fault=PreemptAt(), preemption=guard)
    assert r.get("preempted") is True
    assert ckpt.latest_step(d) == 7  # checkpointed at the preemption point
    r2 = _run(20, ckpt_dir=d)  # resumes and completes
    assert r2["step"] == 20


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(window=10, factor=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.95)  # 9.5x median
    assert not mon.record(11, 0.12)
    assert len(mon.flagged) == 1 and mon.flagged[0][0] == 10


def test_checkpoint_atomicity_no_partial_state(tmp_path):
    """A .tmp directory (simulated crash mid-save) is never picked up."""
    d = tmp_path / "ck4"
    _run(6, ckpt_dir=str(d))
    (d / "step_00000099.tmp").mkdir()
    assert ckpt.latest_step(d) == 6  # ignores the torn write


def test_checkpoint_integrity_detects_corruption(tmp_path):
    d = tmp_path / "ck5"
    r = _run(5, ckpt_dir=str(d))
    step_dir = d / "step_00000005"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    victim = next(iter(manifest["leaves"].values()))["file"]
    arr = np.load(step_dir / victim)
    arr_flat = arr.reshape(-1)
    if arr_flat.size:
        arr_flat[0] = arr_flat[0] + 1 if arr.dtype != np.bool_ else ~arr_flat[0]
    np.save(step_dir / victim, arr)
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.training import optimizer as opt_lib

    params = T.init_params(jax.random.PRNGKey(0), CFG)
    opt_state = opt_lib.init(params, OPT)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(d, 5, {"params": params, "opt": opt_state})


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore onto explicit (1x1) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_debug_mesh

    d = tmp_path / "ck6"
    r = _run(5, ckpt_dir=str(d))
    mesh = make_debug_mesh(1, 1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), r["params"])
    trees, extra = ckpt.restore(
        d, 5, {"params": r["params"]}, shardings={"params": sh}
    )
    for a, b in zip(jax.tree.leaves(trees["params"]), jax.tree.leaves(r["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 5


def test_keep_last_k(tmp_path):
    d = tmp_path / "ck7"
    _run(20, ckpt_dir=str(d))  # saves at 5,10,15,20 (+final)
    ckpt.keep_last_k(d, 2)
    steps = sorted(p.name for p in Path(d).iterdir() if p.name.startswith("step_"))
    assert len(steps) == 2
