"""Flash-prefill attention kernel parity (kernels/flash_prefill.py).

The streaming Pallas kernel (interpret mode on CPU) against the XLA
reference paths (``kv_cache.tiered_chunk_attention`` for continuation,
``blockwise_attention`` composition for fresh prefill) and explicit fp32
oracles. Attention parity is fp32-reference parity to tight tolerance
(streaming merge order differs); the **cache-fill epilogue is asserted
bit-identical** — the emitted rotated k / v must equal the legacy
apply_rope + ``_fill_attn_cache``/``append`` pipeline exactly, fp8 tiers
included.

Covers the ISSUE 5 parity matrix:
  * causal boundaries at every q-block and kv-block edge (fresh prefill,
    blocks that do and don't divide the sequence);
  * SWA window masking, fresh and ring-continuation (wrapped window);
  * ``q_offset`` continuation over a populated tiered cache with mixed
    per-slot offsets AND mixed per-slot valid chunk lengths;
  * fp8(e4m3) cold-tier fill vs the ``_fill_attn_cache`` oracle
    (bit-identical, both tiers);
  * MLA prefill (rope_dims < head, attention-only kernel form);
  * b = 1..8;
  * the models/attention.py + transformer.py wiring: full-model prefill
    under impl="pallas" matches impl="xla" (logits to tolerance, caches
    bit-identical) for dense / SWA / MLA / VLM smoke archs;
  * the "prefill_attn" row of ops.select_blocks.

Everything runs in Pallas interpret mode on CPU — part of the CI
kernel-parity lane (pytest -m kernel_parity).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as kvc
from repro.kernels import flash_prefill as fp
from repro.kernels import ops
from repro.models.layers import apply_rope

pytestmark = pytest.mark.kernel_parity

TOL = dict(rtol=2e-5, atol=2e-5)
THETA = 1e4


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _build_cache(b, hot, cold, g, d, lens, dv=None, dtype=jnp.float32,
                 ring=False, seed=0):
    """Cache with per-slot lengths via active-masked decode appends."""
    dv = dv or d
    cache = kvc.init_cache(b, hot, cold, (g, d), dtype)
    if dv != d:
        cache = cache._replace(
            hot_v=jnp.zeros((b, hot, g, dv), dtype),
            cold_v=jnp.zeros((b, cold, g, dv), dtype),
        )
    app = kvc.append_decode_ring if ring else kvc.append_decode
    for t in range(max(lens)):
        active = jnp.asarray([t < L for L in lens])
        k1 = jax.random.normal(jax.random.PRNGKey(seed + t), (b, g, d))
        v1 = jax.random.normal(jax.random.PRNGKey(seed + 500 + t), (b, g, dv))
        cache = app(cache, k1, v1, active=active)
    return cache


def _qkv(b, c, h, g, dk, dv, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (b, c, h, dk)),
        jax.random.normal(ks[1], (b, c, g, dk)),
        jax.random.normal(ks[2], (b, c, g, dv)),
    )


def _both(q, k, v, cache, **kw):
    got = fp.flash_prefill_attention(q, k, v, cache, impl="pallas",
                                     rope_theta=THETA, **kw)
    want = fp.flash_prefill_attention(q, k, v, cache, impl="xla",
                                      rope_theta=THETA, **kw)
    return got, want


def _assert_parity(got, want, valid=None, o_tol=TOL):
    """Attention rows within each slot's valid count to tolerance; the
    emitted cache-fill k/v bit-identical."""
    emit = isinstance(got, tuple)
    o_g = got[0] if emit else got
    o_w = want[0] if emit else want
    b, c = o_g.shape[:2]
    for i in range(b):
        nv = int(valid[i]) if valid is not None else c
        np.testing.assert_allclose(
            np.asarray(o_g, np.float32)[i, :nv],
            np.asarray(o_w, np.float32)[i, :nv], **o_tol,
        )
    if emit:
        np.testing.assert_array_equal(
            np.asarray(got[1], np.float32), np.asarray(want[1], np.float32))
        np.testing.assert_array_equal(
            np.asarray(got[2], np.float32), np.asarray(want[2], np.float32))


# ---------------------------------------------------------------------------
# fresh aligned prefill: causal boundaries at every block edge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [1, 3, 4, 5, 8, 11, 16])
def test_fresh_causal_every_block_edge(s):
    """block_q = block_s = 4: s sweeps below / at / past every q- and
    kv-block boundary, including non-dividing lengths."""
    q, k, v = _qkv(2, s, 4, 2, 8, 8, seed=s)
    got, want = _both(q, k, v, None, block_q=4, block_s=4)
    _assert_parity(got, want)


def test_fresh_matches_numpy_oracle():
    b, c, h, g, dk = 2, 12, 4, 2, 8
    q, k, v = _qkv(b, c, h, g, dk, dk, seed=9)
    pos = jnp.arange(c, dtype=jnp.int32)[None]
    qr = np.asarray(apply_rope(q, pos, THETA), np.float64)
    kr = np.asarray(apply_rope(k, pos, THETA), np.float64)
    vv = np.asarray(v, np.float64)
    rep = h // g
    oracle = np.zeros((b, c, h, dk))
    for i in range(b):
        for hh in range(h):
            for t in range(c):
                lg = (qr[i, t, hh] @ kr[i, : t + 1, hh // rep].T) * dk**-0.5
                p = np.exp(lg - lg.max())
                oracle[i, t, hh] = (p / p.sum()) @ vv[i, : t + 1, hh // rep]
    got = fp.flash_prefill_attention(
        q, k, v, None, impl="pallas", rope_theta=THETA, emit_kv=False,
        block_q=4, block_s=4,
    )
    np.testing.assert_allclose(np.asarray(got), oracle, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [1, 3, 8])
def test_fresh_swa_window(window):
    q, k, v = _qkv(2, 11, 4, 2, 8, 8, seed=window)
    got, want = _both(q, k, v, None, window=window, block_q=4, block_s=4)
    _assert_parity(got, want)


@pytest.mark.parametrize("b", [1, 2, 5, 8])
def test_fresh_batch_sizes(b):
    q, k, v = _qkv(b, 9, 6, 3, 8, 8, seed=40 + b)
    got, want = _both(q, k, v, None, block_q=4, block_s=4)
    _assert_parity(got, want)


def test_fresh_dv_not_dk():
    q, k, v = _qkv(2, 10, 4, 2, 16, 8, seed=3)
    got, want = _both(q, k, v, None, block_q=4, block_s=4)
    _assert_parity(got, want)


# ---------------------------------------------------------------------------
# q_offset continuation over a populated tiered cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lens,valid", [
    ([0, 5, 12], [8, 8, 8]),      # offsets at 0 / mid-hot / into cold
    ([4, 16, 7], [8, 3, 0]),      # offset at the hot/cold edge; idle slot
    ([3, 9, 15], [1, 5, 8]),      # partial chunks at every tier boundary
])
def test_continuation_mixed_offsets_and_valid(lens, valid):
    b, c, g, d = 3, 8, 2, 8
    cache = _build_cache(b, 4, 12, g, d, lens, seed=sum(lens))
    q, k, v = _qkv(b, c, 4, g, d, d, seed=sum(valid))
    val = jnp.asarray(valid, jnp.int32)
    got, want = _both(q, k, v, cache, valid=val, block_q=4, block_s=4)
    _assert_parity(got, want, valid=valid)


def test_continuation_oracle_prefix_plus_chunk():
    """Explicit fp32 oracle: chunk rows attend [cached prefix ‖ causal
    chunk rows] at absolute positions."""
    b, c, g, h, d = 2, 6, 2, 4, 8
    lens = [5, 11]
    cache = _build_cache(b, 4, 12, g, d, lens, seed=77)
    q, k, v = _qkv(b, c, h, g, d, d, seed=78)
    pos = cache.lengths.astype(jnp.int32)[:, None] + jnp.arange(c)[None]
    qr = np.asarray(apply_rope(q, pos, THETA), np.float64)
    kr = np.asarray(apply_rope(k, pos, THETA), np.float64)
    rep = h // g
    got = fp.flash_prefill_attention(
        q, k, v, cache, impl="pallas", rope_theta=THETA, emit_kv=False,
        block_q=2, block_s=4,
    )
    for i in range(b):
        L = lens[i]
        n_hot = min(L, 4)
        ks_hist = np.concatenate(
            [np.asarray(cache.hot_k[i, :n_hot], np.float64),
             np.asarray(cache.cold_k[i, : L - n_hot], np.float64)])
        vs_hist = np.concatenate(
            [np.asarray(cache.hot_v[i, :n_hot], np.float64),
             np.asarray(cache.cold_v[i, : L - n_hot], np.float64)])
        for t in range(c):
            for hh in range(h):
                gg = hh // rep
                keys = np.concatenate([ks_hist[:, gg], kr[i, : t + 1, gg]])
                vals = np.concatenate([vs_hist[:, gg],
                                       np.asarray(v, np.float64)[i, : t + 1, gg]])
                lg = (qr[i, t, hh] @ keys.T) * d**-0.5
                p = np.exp(lg - lg.max())
                np.testing.assert_allclose(
                    np.asarray(got)[i, t, hh], (p / p.sum()) @ vals,
                    rtol=2e-5, atol=2e-5,
                )


def test_continuation_ring_wrapped_window():
    """SWA ring continuation: the chunk's later rows slide the window past
    the oldest ring entries — absolute ring positions must mask them."""
    b, c, g, d, w = 2, 6, 2, 8, 8
    lens = [10, 3]  # slot 0 wrapped, slot 1 not
    cache = _build_cache(b, 0, w, g, d, lens, ring=True, seed=31)
    q, k, v = _qkv(b, c, 4, g, d, d, seed=32)
    val = jnp.asarray([6, 4], jnp.int32)
    got, want = _both(q, k, v, cache, valid=val, window=w, ring=True,
                      block_q=2, block_s=4)
    _assert_parity(got, want, valid=[6, 4])


def test_continuation_ring_non_dividing_block():
    """Ring window NOT a multiple of the S-block (w=6, block_s=4): the
    partial last cold block's padding columns must not wrap back into
    valid positions via the modulo (regression: uninitialized rows fed
    the softmax as NaN)."""
    b, c, g, d, w = 2, 4, 2, 8, 6
    lens = [9, 5]  # wrapped and unwrapped
    cache = _build_cache(b, 0, w, g, d, lens, ring=True, seed=41)
    q, k, v = _qkv(b, c, 4, g, d, d, seed=42)
    val = jnp.asarray([4, 3], jnp.int32)
    got, want = _both(q, k, v, cache, valid=val, window=w, ring=True,
                      block_q=2, block_s=4)
    assert np.isfinite(np.asarray(got[0], np.float32)).all()
    _assert_parity(got, want, valid=[4, 3])


# ---------------------------------------------------------------------------
# cache-fill epilogue: bit-identical to the legacy fill, fp8 included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float8_e4m3fn])
def test_fill_matches_fill_attn_cache_oracle(dtype):
    """The cache-fill epilogue against the legacy pipeline, decomposed
    into its two contracts:

    * **rotation** — the kernel's in-kernel RoPE vs a standalone
      ``apply_rope`` graph agree to 1-2 f32 ulp (the expressions are
      identical; XLA fuses the multiply-adds differently across
      compilation contexts, so strict cross-graph bit-equality is not
      achievable — within one pairing, e.g. the pallas-vs-xla entry
      emits asserted all over this file, equality IS bitwise);
    * **placement + tier-dtype cast** — feeding the same rotated rows to
      ``fill_fresh`` (the kernel path's placement) and to the legacy
      ``_fill_attn_cache`` one-hot pass must fill hot AND cold tier
      bit-identically, fp8/bf16 quantization included.
    """
    from repro.models import transformer as T

    b, s, g, d, hot, cold = 2, 12, 2, 8, 4, 16
    _, k, v = _qkv(b, s, 4, g, d, d, seed=5)
    q = jax.random.normal(jax.random.PRNGKey(6), (b, s, 4, d))
    _, k_c, v_c = fp.flash_prefill_attention(
        q, k, v, None, impl="pallas", rope_theta=THETA, emit_kv=True,
        kv_dtype=dtype, block_q=4, block_s=4,
    )
    # rotation parity vs a standalone apply_rope graph (ulp-level)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    kr = apply_rope(k, pos, THETA).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(k_c, np.float32), np.asarray(kr, np.float32),
        rtol=1e-6, atol=1e-6,
    )
    np.testing.assert_array_equal(
        np.asarray(v_c, np.float32), np.asarray(v.astype(dtype), np.float32))
    # placement + cast parity, bitwise, on the same rotated rows:
    # fill_fresh (the static-slice path _fill_attn_cache now delegates
    # to) vs the historical one-hot append on a fresh cache
    fresh = kvc.init_cache(b, hot, cold, (g, d), dtype)
    filled = kvc.fill_fresh(fresh, k_c, v_c)
    legacy = kvc.append(fresh, k_c, v_c)
    for name in ("hot_k", "hot_v", "cold_k", "cold_v", "lengths"):
        np.testing.assert_array_equal(
            np.asarray(getattr(filled, name), np.float32),
            np.asarray(getattr(legacy, name), np.float32), err_msg=name,
        )


def test_fill_swa_ring_realign_matches_legacy():
    """SWA s > window: fill_fresh's ring realign (the single home of the
    ring-layout invariant — _fill_attn_cache delegates here) against an
    independent oracle: ring slot j must hold the token with position
    p ≡ j (mod w) from the last w."""
    b, s, g, d, w = 2, 13, 2, 8, 8  # s > window: realign path
    _, k, v = _qkv(b, s, 4, g, d, d, seed=8)
    q = jax.random.normal(jax.random.PRNGKey(9), (b, s, 4, d))
    _, k_c, v_c = fp.flash_prefill_attention(
        q, k, v, None, impl="pallas", rope_theta=THETA, emit_kv=True,
        window=w, block_q=4, block_s=4,
    )
    fresh = kvc.init_cache(b, 0, w, (g, d), jnp.float32)
    filled = kvc.fill_fresh(fresh, k_c, v_c, ring=True)
    np.testing.assert_array_equal(np.asarray(filled.lengths), [s, s])
    for j in range(w):
        p = max(pp for pp in range(s) if pp % w == j)  # last writer of slot j
        np.testing.assert_array_equal(
            np.asarray(filled.cold_k[:, j]), np.asarray(k_c[:, p]),
            err_msg=f"slot {j} != position {p}",
        )
        np.testing.assert_array_equal(
            np.asarray(filled.cold_v[:, j]), np.asarray(v_c[:, p]))
    # and ring append on a fresh cache (the decode write path) agrees
    legacy = kvc.append(fresh, k_c, v_c, ring=True)
    for name in ("cold_k", "cold_v", "lengths"):
        np.testing.assert_array_equal(
            np.asarray(getattr(filled, name)),
            np.asarray(getattr(legacy, name)), err_msg=name,
        )


def test_chunk_append_fp8_matches_xla_fill():
    """Continuation fill: kernel-emitted fp8 chunk rows scattered by
    kv_cache.append(valid=) == the XLA-rotated rows scattered the same
    way, bitwise."""
    b, c, g, d = 2, 6, 2, 8
    lens = [4, 9]
    cache = _build_cache(b, 4, 12, g, d, lens, dtype=jnp.float8_e4m3fn, seed=51)
    q, k, v = _qkv(b, c, 4, g, d, d, seed=52)
    val = jnp.asarray([6, 3], jnp.int32)
    got, want = _both(q, k, v, cache, valid=val, block_q=2, block_s=4)
    filled_p = kvc.append(cache, got[1], got[2], valid=val)
    filled_x = kvc.append(cache, want[1], want[2], valid=val)
    for a, bb in zip(jax.tree.leaves(filled_p), jax.tree.leaves(filled_x)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(bb, np.float32))


# ---------------------------------------------------------------------------
# MLA (rope_dims < head dim, attention-only form) + model-level wiring
# ---------------------------------------------------------------------------


def test_mla_rope_dims_parity():
    b, c, h, dk, dv, rd = 2, 9, 4, 24, 16, 8
    q, k, v = _qkv(b, c, h, h, dk, dv, seed=61)  # g = h, rep = 1
    cache = _build_cache(b, 2, 10, h, dk, [3, 7], dv=dv, seed=62)
    got, want = _both(q, k, v, cache, rope_dims=rd, emit_kv=False,
                      block_q=4, block_s=4)
    _assert_parity(got, want)


def _impl_cfg(cfg, impl):
    return dataclasses.replace(
        cfg, bitnet=dataclasses.replace(cfg.bitnet, impl=impl)
    )


@pytest.mark.parametrize("arch", [
    "falcon3-1b", "mixtral-8x22b", "deepseek-v3-671b", "llava-next-34b",
])
def test_model_prefill_impl_parity(arch):
    """transformer.prefill under impl='pallas' (flash scan path) vs
    impl='xla' (legacy collect-KV forward + bulk fill): last-token logits
    to tolerance, every cache leaf bit-identical."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, p_len = 2, 11
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(7), (b, p_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(8), (b, cfg.n_patches, cfg.frontend_dim))
    lg_x, cache_x = T.prefill(
        params, _impl_cfg(cfg, "xla"), batch, hot_cap=4, max_len=24, mode="qat")
    lg_p, cache_p = T.prefill(
        params, _impl_cfg(cfg, "pallas"), batch, hot_cap=4, max_len=24, mode="qat")
    np.testing.assert_allclose(
        np.asarray(lg_p), np.asarray(lg_x), rtol=2e-4, atol=2e-4)
    # cache parity is ulp-level across the two *pipelines* (the in-kernel
    # rope and apply_rope fuse differently under XLA); placement itself
    # is asserted bitwise in the fill oracle tests above
    for lx, lp in zip(jax.tree.leaves(cache_x), jax.tree.leaves(cache_p)):
        np.testing.assert_allclose(
            np.asarray(lx, np.float32), np.asarray(lp, np.float32),
            rtol=1e-6, atol=1e-6)


def test_attention_prefill_chunk_impl_parity():
    """attention_prefill_chunk: pallas and xla produce the same outputs
    (tolerance) and the same post-append cache (bitwise)."""
    from repro.configs import get_smoke_config
    from repro.models import attention as attn

    cfg = get_smoke_config("falcon3-1b")
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    b, c = 3, 4
    lens = [0, 5, 9]
    cache = _build_cache(b, 4, 12, g, hd, lens, seed=71)
    x = jax.random.normal(jax.random.PRNGKey(72), (b, c, cfg.d_model)) * 0.1
    n_valid = jnp.asarray([4, 2, 0], jnp.int32)
    outs = {}
    for impl in ("pallas", "xla"):
        y, c2 = attn.attention_prefill_chunk(
            p, x, _impl_cfg(cfg, impl), "qat", cache, n_valid, impl=impl)
        outs[impl] = (np.asarray(y), c2)
    for i, nv in enumerate([4, 2, 0]):
        np.testing.assert_allclose(
            outs["pallas"][0][i, :nv], outs["xla"][0][i, :nv], **TOL)
    for a, bb in zip(jax.tree.leaves(outs["pallas"][1]),
                     jax.tree.leaves(outs["xla"][1])):
        # ulp-level: kernel rope vs apply_rope under different XLA fusion
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# block table
# ---------------------------------------------------------------------------


def test_select_blocks_prefill_attn_kind():
    # GQA rep row: 128-token q blocks, 256-token S-blocks, capped at C
    assert ops.select_blocks(4, 128, 4096, "pack2", kind="prefill_attn") == (
        128, 128, 256)
    assert ops.select_blocks(4, 128, 48, "pack2", kind="prefill_attn") == (
        48, 128, 48)
    # MLA row (rep > 16 never happens, but wide-head rows halve)
    assert ops.select_blocks(64, 576, 4096, "pack2", kind="prefill_attn") == (
        64, 128, 128)
    # codec is ignored for this kind (no packed operand)
    assert ops.select_blocks(4, 128, 4096, "pack243", kind="prefill_attn") == (
        128, 128, 256)
