"""Test-suite bootstrap: make ``hypothesis`` optional, bound jit-cache
memory mappings.

The property tests in test_kernels / test_packing / test_ternary use
hypothesis when it is installed (``pip install -e .[property]``). On bare
environments this shim installs a stub module so those files still
*collect* and their plain unit tests run; only the ``@given`` property
tests are skipped, with a clear reason.

The module-scoped autouse fixture below releases jax's global
compilation caches between test modules. Without it the suite's
hundreds of Engine builds accumulate XLA executables (each one holds
several ``mmap`` regions even after the engine is garbage-collected —
the global jit caches pin them) until the process hits the kernel's
``vm.max_map_count`` (65530 by default) and the next compile segfaults
inside XLA. Clearing per module keeps the map count bounded by the
heaviest single module instead of the whole suite.
"""

from __future__ import annotations

import gc
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _install_hypothesis_stub() -> None:
    def given(*_args, **_kwargs):
        def deco(fn):
            # NOTE: no functools.wraps — it would forward the wrapped
            # function's signature and pytest would then demand fixtures
            # for the strategy parameters. Bare *args keeps pytest happy.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (pip install .[property])")

            skipper.__name__ = fn.__name__
            skipper.__module__ = fn.__module__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Placeholder returned by strategy constructors; never executed."""

        def __repr__(self):  # pragma: no cover
            return "<stub strategy>"

    strategies = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers", "floats", "booleans", "sampled_from", "lists", "tuples",
        "just", "one_of", "text", "binary", "composite",
    ):
        setattr(strategies, name, lambda *a, **k: _Strategy())

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


if not HAVE_HYPOTHESIS:
    _install_hypothesis_stub()


@pytest.fixture(autouse=True, scope="module")
def _release_jit_executables():
    """Drop jax's global compilation caches after every test module (see
    module docstring: unreleased XLA executables exhaust
    ``vm.max_map_count`` over a full tier-1 run). Costs cross-module
    cache reuse, which is small — modules compile their own shapes."""
    yield
    import jax

    jax.clear_caches()
    gc.collect()
