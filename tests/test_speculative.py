"""Speculative decoding: bit-exact greedy parity + rollback correctness.

The speculative engine's contract (docs/serving.md, "Speculative
decoding") is that the draft model can only change HOW FAST tokens are
emitted, never WHICH tokens — every emitted token is the target's own
argmax. These tests pin that end-to-end:

  * **bit-exactness** across cache families (dense, MoE, SWA ring) and
    layouts (contiguous, paged) for K in {1, 2, 4, 8}, against the
    non-speculative engine's greedy output;
  * the **degenerate mixes**: forced full-reject (``spec_force``, the
    maximal-rollback path CI pins) and full-accept (draft == target);
  * **rollback hygiene**: the paged refcount census
    (``check_serving_invariants``) after every loop iteration, including
    its speculation check — no page the rejected suffix transiently
    occupied stays live;
  * **accounting**: drafted/accepted ledgers reconcile exactly with the
    emitted token counts, per request and in aggregate;
  * the **control plane**: cancellation and preemption landing mid-
    speculation (and mid-draft-prefill);
  * a **property test** for ``kv_cache.truncate``: random
    append/truncate/append sequences are indistinguishable from a
    from-scratch rebuild of the surviving rows, on contiguous and paged
    caches alike (hypothesis-driven when installed, fixed seeds always).
"""

import random
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core import kv_cache
from repro.models import transformer as T
from repro.serving import speculative as spec_lib
from repro.serving.chaos import check_serving_invariants
from repro.serving.engine import Engine
from repro.serving.scheduler import Request

HOT, ML = 4, 64


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


def _setup(name):
    cfg = get_smoke_config(name)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    return cfg, params, dcfg, dparams


@pytest.fixture(scope="module")
def dense():
    return _setup("falcon3-1b")


@pytest.fixture(scope="module")
def swa():
    return _setup("mixtral-8x22b")  # smoke mixtral is the SWA-ring config


@pytest.fixture(scope="module")
def moe():
    return _setup("gemma-7b")  # full-attention; mixtral covers MoE+SWA


def _spec_engine(cfg, params, dcfg, dparams, k, paged=False, **kw):
    kw.setdefault("hot_cap", HOT)
    kw.setdefault("max_len", ML)
    kw.setdefault("prefill_chunk", 4)
    if paged:
        kw.setdefault("page_size", 8)
        kw["paged"] = True
    return Engine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                  spec_k=k, **kw)


# ---------------------------------------------------------------------------
# the acceptance kernel (pure function)
# ---------------------------------------------------------------------------


def _lap(chunk, greedy, valid, **kw):
    return np.asarray(spec_lib.longest_accepted_prefix(
        jnp.asarray(chunk, jnp.int32), jnp.asarray(greedy, jnp.int32),
        jnp.asarray(valid, jnp.int32), **kw))


def test_acceptance_kernel_prefix_rule():
    # chunk[0] always emits; proposal i accepted iff it equals the
    # target's continuation of position i-1 AND everything before held
    chunk = [[5, 7, 9, 4]]
    greedy = [[7, 9, 1, 0]]  # 7 ok, 9 ok, 4 != 1 -> emit 3
    assert _lap(chunk, greedy, [4]) == [3]
    # first proposal already wrong: only the pending token emits
    assert _lap(chunk, [[6, 9, 1, 0]], [4]) == [1]
    # everything matches: whole chunk emits
    assert _lap([[5, 7, 9, 4]], [[7, 9, 4, 2]], [4]) == [4]
    # a hole does not recover even if later positions match again
    assert _lap([[5, 7, 9, 4]], [[7, 0, 4, 2]], [4]) == [2]


def test_acceptance_kernel_valid_and_reject():
    chunk = [[5, 7, 9, 4]]
    greedy = [[7, 9, 4, 2]]
    assert _lap(chunk, greedy, [2]) == [2]  # clipped by chunk_valid
    assert _lap(chunk, greedy, [1]) == [1]
    assert _lap(chunk, greedy, [0]) == [0]  # inactive slot emits nothing
    assert _lap(chunk, greedy, [4], force_reject=True) == [1]


def test_acceptance_kernel_stop_clip():
    # the sequential loop retires a slot the moment the TARGET samples
    # the stop token: speculation must not emit past that position even
    # when the draft predicted the stop correctly
    chunk = [[5, 7, 9, 4]]
    greedy = [[7, 9, 4, 2]]
    assert _lap(chunk, greedy, [4], stop_token=9) == [2]
    assert _lap(chunk, greedy, [4], stop_token=7) == [1]
    assert _lap(chunk, greedy, [4], stop_token=2) == [4]
    # stop past chunk_valid is invisible this round
    assert _lap(chunk, greedy, [2], stop_token=4) == [2]


# ---------------------------------------------------------------------------
# bit-exact end-to-end parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["dense", "swa", "moe"])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_bitexact_contiguous(arch, k, request):
    """Speculative greedy == sequential greedy, token for token, for
    every draft quality (a random draft gives a mixed accept/reject
    stream) across the dense / MoE / SWA-ring cache families."""
    cfg, params, dcfg, dparams = request.getfixturevalue(arch)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (3, 9), 0, cfg.vocab_size)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = np.asarray(base.generate(prompts, max_new_tokens=12).tokens)
    eng = _spec_engine(cfg, params, dcfg, dparams, k)
    assert eng.spec
    res = eng.generate(prompts, max_new_tokens=12)
    np.testing.assert_array_equal(ref, np.asarray(res.tokens))
    st_ = eng.last_stats
    assert st_.accepted_tokens <= st_.drafted_tokens
    if k == 1:
        assert st_.drafted_tokens == 0  # K=1 proposes nothing


@pytest.mark.parametrize("k", [2, 8])
def test_bitexact_paged_with_invariants(dense, k):
    """Paged speculation: the commit-then-truncate rollback plus the
    trailing-page decref leave the refcount protocol intact after EVERY
    loop iteration, and the tokens still match the non-speculative run."""
    cfg, params, dcfg, dparams = dense
    prompts = np.stack([_prompt(20 + i, 9, cfg.vocab_size) for i in range(3)])
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                  paged=True, page_size=8)
    ref = np.asarray(base.generate(jnp.asarray(prompts),
                                   max_new_tokens=20).tokens)
    eng = _spec_engine(cfg, params, dcfg, dparams, k, paged=True)
    reqs = [Request(i, prompts[i], 20) for i in range(3)]
    fins = {f.rid: f for f in eng.serve(
        reqs, slots=3, on_iteration=check_serving_invariants)}
    for i in range(3):
        np.testing.assert_array_equal(ref[i], fins[i].tokens)


def test_bitexact_with_stop_token(dense):
    """Stop handling mid-chunk: a slot retires exactly where the
    sequential loop would, with the stop token left pending/unemitted."""
    cfg, params, dcfg, dparams = dense
    prompts = jax.random.randint(
        jax.random.PRNGKey(3), (3, 8), 0, cfg.vocab_size)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    free = np.asarray(base.generate(prompts, max_new_tokens=12).tokens)
    # pick a token that actually occurs mid-stream so the clip matters
    stop = int(free[0, 4])
    ref = base.generate(prompts, max_new_tokens=12, stop_token=stop)
    for k in (2, 4):
        eng = _spec_engine(cfg, params, dcfg, dparams, k)
        res = eng.generate(prompts, max_new_tokens=12, stop_token=stop)
        np.testing.assert_array_equal(
            np.asarray(ref.tokens), np.asarray(res.tokens))
        assert ref.steps_per_row == res.steps_per_row


# ---------------------------------------------------------------------------
# degenerate accept mixes + accounting
# ---------------------------------------------------------------------------


def test_full_accept_and_ledger(dense):
    """Draft == target accepts every proposal: each round emits
    min(K, remaining) tokens, so the ledger is exactly predictable."""
    cfg, params, _, _ = dense
    k, new = 4, 14
    prompts = jax.random.randint(
        jax.random.PRNGKey(5), (2, 9), 0, cfg.vocab_size)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = np.asarray(base.generate(prompts, max_new_tokens=new).tokens)
    eng = _spec_engine(cfg, params, cfg, params, k)
    reqs = [Request(i, np.asarray(prompts)[i], new) for i in range(2)]
    fins = {f.rid: f for f in eng.serve(reqs, slots=2)}
    rounds = -(-new // k)  # every round emits min(K, remaining)
    for i in range(2):
        f = fins[i]
        np.testing.assert_array_equal(ref[i], f.tokens)
        assert f.accepted_tokens == f.drafted_tokens == new - rounds
        assert f.acceptance_rate == 1.0
        # the speculation identity: emitted == accepted + rounds
        assert len(f.tokens) == f.accepted_tokens + rounds
    st_ = eng.last_stats
    assert st_.drafted_tokens == sum(f.drafted_tokens for f in fins.values())
    assert st_.accepted_tokens == sum(f.accepted_tokens for f in fins.values())


@pytest.mark.parametrize("paged", [False, True])
def test_forced_full_reject(dense, paged):
    """``spec_force="reject"`` statically rejects every proposal: each
    round emits exactly one token through the maximal-rollback path —
    deterministic worst case for CI — and outputs stay bit-exact."""
    cfg, params, dcfg, dparams = dense
    k, new = 4, 12
    prompts = jax.random.randint(
        jax.random.PRNGKey(6), (2, 9), 0, cfg.vocab_size)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = np.asarray(base.generate(prompts, max_new_tokens=new).tokens)
    eng = _spec_engine(cfg, params, dcfg, dparams, k, paged=paged,
                       spec_force="reject")
    reqs = [Request(i, np.asarray(prompts)[i], new) for i in range(2)]
    fins = {f.rid: f for f in eng.serve(
        reqs, slots=2,
        on_iteration=check_serving_invariants if paged else None)}
    # full reject: one round per token; round r drafts min(K, new-r) - 1
    drafted = sum(min(k, new - r) - 1 for r in range(new))
    for i in range(2):
        np.testing.assert_array_equal(ref[i], fins[i].tokens)
        assert fins[i].accepted_tokens == 0
        assert fins[i].drafted_tokens == drafted
        assert fins[i].acceptance_rate == 0.0


def test_spec_step_compiles_once(dense):
    """The draft-verify round is one cached compilation per (out_cap,
    stop) — serving twice must not re-trace."""
    cfg, params, dcfg, dparams = dense
    eng = _spec_engine(cfg, params, dcfg, dparams, 4)
    prompts = jax.random.randint(
        jax.random.PRNGKey(8), (2, 6), 0, cfg.vocab_size)
    eng.generate(prompts, max_new_tokens=6)
    eng.generate(prompts, max_new_tokens=6)
    assert len(eng._spec_step_fns) == 1
    (fn,) = eng._spec_step_fns.values()
    assert fn._cache_size() == 1
    assert eng._draft_chunk_fn._cache_size() == 1


# ---------------------------------------------------------------------------
# control plane: cancel / preempt mid-speculation
# ---------------------------------------------------------------------------


def test_cancel_mid_speculation(dense):
    """A cancel landing between draft-verify rounds harvests a clean
    prefix of the uncancelled output and a consistent ledger."""
    cfg, params, dcfg, dparams = dense
    prompts = np.stack([_prompt(40 + i, 8, cfg.vocab_size) for i in range(2)])
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = np.asarray(base.generate(jnp.asarray(prompts),
                                   max_new_tokens=20).tokens)
    eng = _spec_engine(cfg, params, dcfg, dparams, 4)

    def hook(ctx):
        if ctx.iteration == 1:
            eng.cancel(0)

    reqs = [Request(i, prompts[i], 20) for i in range(2)]
    fins = {f.rid: f for f in eng.serve(
        reqs, slots=2, sync_every=2, on_iteration=hook)}
    f0 = fins[0]
    assert f0.outcome == "cancelled"
    assert 0 < len(f0.tokens) < 20
    np.testing.assert_array_equal(ref[0, : len(f0.tokens)], f0.tokens)
    assert f0.accepted_tokens <= f0.drafted_tokens
    assert fins[1].outcome == "finished"
    np.testing.assert_array_equal(ref[1], fins[1].tokens)
    assert eng.last_stats.cancelled == 1


def test_cancel_mid_draft_prefill(dense):
    """A cancel landing while the DRAFT cache is still streaming its
    prompt pops both prefill trackers and leaves the engine serving."""
    cfg, params, dcfg, dparams = dense
    long, short = _prompt(50, 24, cfg.vocab_size), _prompt(51, 6, cfg.vocab_size)
    eng = _spec_engine(cfg, params, dcfg, dparams, 4)

    def hook(ctx):
        if ctx.iteration == 1:
            eng.cancel(0)

    fins = {f.rid: f for f in eng.serve(
        [Request(0, long, 8), Request(1, short, 8)],
        slots=2, sync_every=1, on_iteration=hook)}
    assert fins[0].outcome == "cancelled"
    assert fins[1].outcome == "finished" and len(fins[1].tokens) == 8
    ctx = eng._last_ctx
    assert not ctx.prefilling and not ctx.draft_prefilling


def test_preemption_mid_speculation_bit_exact(dense):
    """Page pressure preempting a slot between speculative rounds:
    recompute-from-prefix (target AND draft cache rebuilt) keeps greedy
    output bit-identical, carries the drafted/accepted counters across
    attempts, and the refcount census holds every iteration."""
    cfg, params, dcfg, dparams = dense
    reqs = [Request(i, _prompt(60 + i, 10 + i, cfg.vocab_size), 16)
            for i in range(4)]
    big = _spec_engine(cfg, params, dcfg, dparams, 4, paged=True)
    fin_big = {f.rid: f for f in big.serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs],
        slots=2, sync_every=4)}
    assert big.last_stats.preemptions == 0
    small = _spec_engine(cfg, params, dcfg, dparams, 4, paged=True,
                         n_pages=6)
    fins = {f.rid: f for f in small.serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs],
        slots=2, sync_every=4, on_iteration=check_serving_invariants)}
    assert small.last_stats.preemptions > 0
    for rid, f in fins.items():
        assert f.outcome == "finished"
        np.testing.assert_array_equal(fin_big[rid].tokens, f.tokens)
        assert f.accepted_tokens <= f.drafted_tokens
    st_ = small.last_stats
    assert st_.drafted_tokens == sum(f.drafted_tokens for f in fins.values())
    assert st_.accepted_tokens == sum(f.accepted_tokens for f in fins.values())


# ---------------------------------------------------------------------------
# construction gates
# ---------------------------------------------------------------------------


def test_incapable_arch_falls_back_with_warning():
    cfg = get_smoke_config("mamba2-130m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    with pytest.warns(RuntimeWarning, match="falls back"):
        eng = Engine(cfg, params, hot_cap=HOT, max_len=48,
                     draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    assert not eng.spec
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=4)
    assert res.tokens.shape == (2, 4)
    assert eng.last_stats.drafted_tokens == 0


def test_construction_gates(dense):
    cfg, params, dcfg, dparams = dense
    with pytest.raises(NotImplementedError, match="greedy-only"):
        Engine(cfg, params, prefill_chunk=4, sample="temperature",
               draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    import dataclasses
    bad = dataclasses.replace(dcfg, vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        Engine(cfg, params, prefill_chunk=4, draft_cfg=bad,
               draft_params=dparams, spec_k=4)
    with pytest.raises(ValueError, match="draft_cfg"):
        Engine(cfg, params, prefill_chunk=4, draft_params=dparams, spec_k=4)
    with pytest.raises(ValueError, match="spec_force"):
        Engine(cfg, params, prefill_chunk=4, draft_cfg=dcfg,
               draft_params=dparams, spec_k=4, spec_force="accept")


def test_rejection_sampling_stub_names_the_gap():
    with pytest.raises(NotImplementedError, match="rejection"):
        spec_lib.rejection_sample()


# ---------------------------------------------------------------------------
# kv_cache.truncate: property test (hypothesis + seeded fallback)
# ---------------------------------------------------------------------------


def _cache_rows(cache, s, n):
    """Slot ``s``'s first ``n`` effective KV rows, hot then cold."""
    t = (kv_cache.as_tiered(cache)
         if isinstance(cache, kv_cache.PagedKVCache) else cache)
    k = np.concatenate([np.asarray(t.hot_k[s]), np.asarray(t.cold_k[s])])
    v = np.concatenate([np.asarray(t.hot_v[s]), np.asarray(t.cold_v[s])])
    return k[:n], v[:n]


def _run_truncate_fuzz(seed, paged):
    """Random append/truncate/append sequence vs a from-scratch rebuild:
    the cache's effective rows (everything reads are allowed to see)
    must be indistinguishable after every op, and a final rebuild from
    the surviving history must match row for row — i.e. truncate is
    exactly 'forget the suffix', nothing more."""
    rng = random.Random(seed)
    b, hot, cold, ps = 2, 3, 12, 4
    kv_shape = (2,)
    cap = hot + cold

    def fresh():
        if paged:
            return kv_cache.init_paged_cache(
                b, hot, cold, kv_shape, jnp.float32, page_size=ps)
        return kv_cache.init_cache(b, hot, cold, kv_shape, jnp.float32)

    cache = fresh()
    hist = [[] for _ in range(b)]  # python mirror of each slot's rows
    stamp = 1.0

    def check():
        assert list(np.asarray(cache.lengths)) == [len(h) for h in hist]
        for s in range(b):
            if hist[s]:
                k, v = _cache_rows(cache, s, len(hist[s]))
                want = np.stack([r[0] for r in hist[s]])
                np.testing.assert_array_equal(k, want)
                np.testing.assert_array_equal(
                    v, np.stack([r[1] for r in hist[s]]))

    for _ in range(rng.randrange(5, 14)):
        if rng.random() < 0.55:
            t = rng.randrange(1, 5)
            valid = np.zeros((b,), np.int32)
            k_new = np.zeros((b, t) + kv_shape, np.float32)
            v_new = np.zeros((b, t) + kv_shape, np.float32)
            for s in range(b):
                valid[s] = rng.randrange(0, min(t, cap - len(hist[s])) + 1)
                for i in range(int(valid[s])):
                    k_new[s, i] = stamp
                    v_new[s, i] = -stamp
                    hist[s].append((k_new[s, i].copy(), v_new[s, i].copy()))
                    stamp += 1.0
            cache = kv_cache.append(
                cache, jnp.asarray(k_new), jnp.asarray(v_new),
                valid=jnp.asarray(valid))
        else:
            new_len = np.asarray(
                [rng.randrange(0, len(h) + 1) for h in hist], np.int32)
            cache = kv_cache.truncate(cache, jnp.asarray(new_len))
            for s in range(b):
                hist[s] = hist[s][: new_len[s]]
        check()

    # from-scratch rebuild of the surviving history == the fuzzed cache
    rebuilt = fresh()
    t_max = max((len(h) for h in hist), default=0)
    if t_max:
        k_new = np.zeros((b, t_max) + kv_shape, np.float32)
        v_new = np.zeros((b, t_max) + kv_shape, np.float32)
        for s in range(b):
            for i, (kr, vr) in enumerate(hist[s]):
                k_new[s, i], v_new[s, i] = kr, vr
        rebuilt = kv_cache.append(
            rebuilt, jnp.asarray(k_new), jnp.asarray(v_new),
            valid=jnp.asarray([len(h) for h in hist], np.int32))
    for s in range(b):
        ka, va = _cache_rows(cache, s, len(hist[s]))
        kb, vb = _cache_rows(rebuilt, s, len(hist[s]))
        np.testing.assert_array_equal(ka, kb)
        np.testing.assert_array_equal(va, vb)


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_truncate_fuzz_seeded(seed, paged):
    """Always-on fallback of the hypothesis property below."""
    _run_truncate_fuzz(seed, paged)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), paged=st.booleans())
def test_truncate_fuzz_property(seed, paged):
    _run_truncate_fuzz(seed, paged)
