"""Cross-config serving conformance matrix.

One behavioural contract, every config in ``repro.configs``: the serving
engine must either serve a config correctly or refuse it loudly — never
a third thing. Concretely, for each registered config (smoke-sized):

  * **decoder families** (dense / MoE / SSM / hybrid / VLM) generate
    deterministically through ``Engine.generate`` — same prompts, same
    tokens, run over run;
  * the **encoder-only family** (audio) is refused with a ``ValueError``
    naming the family — it has no decode phase to serve;
  * **speculative decoding** partitions the registry the same way
    chunked prefill does: chunk-capable attention families (dense/MoE
    with full or sliding-window attention, no frontend) run draft-verify
    rounds and stay bit-identical to their own non-speculative greedy
    output; everything else (MLA, SSM, hybrid, VLM) falls back to
    non-speculative decode with an asserted ``RuntimeWarning`` and then
    serves exactly as before;
  * **paged + speculative** composes on the full-attention subset, with
    the refcount/rollback invariants checked every loop iteration.

The matrix is in the full-CI lane (``slow`` marker): it compiles a pair
of engines per config, which is minutes of work, not seconds.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_configs
from repro.models import transformer as T
from repro.serving import speculative as spec_lib
from repro.serving.chaos import check_serving_invariants
from repro.serving.engine import Engine
from repro.serving.scheduler import Request

pytestmark = pytest.mark.slow

HOT, ML, NEW = 4, 64, 8

ALL = list_configs()


def _chunk_capable(cfg):
    # mirror of Engine._chunked_capable, kept separate on purpose: if the
    # engine's notion of capability drifts, the matrix below fails on the
    # config that moved rather than silently re-sorting itself
    return (cfg.family in ("dense", "moe")
            and cfg.attn_type in ("full", "swa")
            and cfg.frontend == "none")


AUDIO = [n for n in ALL if get_smoke_config(n).family == "audio"]
SPEC_OK = [n for n in ALL if _chunk_capable(get_smoke_config(n))]
FALLBACK = [n for n in ALL
            if n not in AUDIO and not _chunk_capable(get_smoke_config(n))]
PAGED_OK = [n for n in SPEC_OK if get_smoke_config(n).attn_type == "full"]

_setup_cache = {}


def _setup(name):
    if name not in _setup_cache:
        cfg = get_smoke_config(name)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _setup_cache[name] = (cfg, params)
    return _setup_cache[name]


def _inputs(cfg, b=2, n=8):
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (b, n), 0, cfg.vocab_size)
    patches = None
    if cfg.family == "vlm":
        patches = np.zeros((b, cfg.n_patches, cfg.frontend_dim), np.float32)
    return prompts, patches


def test_registry_partition_is_total():
    """Every config lands in exactly one serving category — a new config
    that fits none of them must extend this matrix, not skip it."""
    assert sorted(AUDIO + SPEC_OK + FALLBACK) == sorted(ALL)
    assert AUDIO  # the encoder-refusal path stays exercised
    assert "mamba2-130m" in FALLBACK and "zamba2-7b" in FALLBACK
    assert "falcon3-draft" in SPEC_OK  # the draft model serves standalone


@pytest.mark.parametrize("name", [n for n in ALL if n not in AUDIO])
def test_generate_deterministic(name):
    cfg, params = _setup(name)
    prompts, patches = _inputs(cfg)
    eng = Engine(cfg, params, hot_cap=HOT, max_len=ML)
    a = eng.generate(prompts, max_new_tokens=NEW, patches=patches)
    b = eng.generate(prompts, max_new_tokens=NEW, patches=patches)
    assert a.tokens.shape == (2, NEW)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


@pytest.mark.parametrize("name", AUDIO)
def test_encoder_only_is_refused(name):
    cfg, params = _setup(name)
    prompts, _ = _inputs(cfg)
    eng = Engine(cfg, params, hot_cap=HOT, max_len=ML)
    with pytest.raises(ValueError, match=cfg.family):
        eng.generate(prompts, max_new_tokens=NEW)


@pytest.mark.parametrize("name", SPEC_OK)
def test_speculative_parity(name):
    """Chunk-capable configs: draft-verify greedy == sequential greedy,
    token for token."""
    cfg, params = _setup(name)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    prompts, _ = _inputs(cfg)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = base.generate(prompts, max_new_tokens=NEW)
    eng = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                 draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    assert eng.spec
    res = eng.generate(prompts, max_new_tokens=NEW)
    np.testing.assert_array_equal(
        np.asarray(ref.tokens), np.asarray(res.tokens))
    st = eng.last_stats
    assert st.accepted_tokens <= st.drafted_tokens
    assert st.drafted_tokens > 0


@pytest.mark.parametrize("name", FALLBACK)
def test_speculative_fallback_warns_then_serves(name):
    """MLA / SSM / hybrid / VLM configs cannot run the chunked verify
    dispatch: the engine must say so (RuntimeWarning) and then serve
    identically to a plain engine — never crash, never go silent."""
    cfg, params = _setup(name)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    prompts, patches = _inputs(cfg)
    plain = Engine(cfg, params, hot_cap=HOT, max_len=ML)
    ref = plain.generate(prompts, max_new_tokens=NEW, patches=patches)
    with pytest.warns(RuntimeWarning, match="falls back"):
        eng = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                     draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    assert not eng.spec
    res = eng.generate(prompts, max_new_tokens=NEW, patches=patches)
    np.testing.assert_array_equal(
        np.asarray(ref.tokens), np.asarray(res.tokens))
    assert eng.last_stats.drafted_tokens == 0


@pytest.mark.parametrize("name", PAGED_OK)
def test_paged_speculative_parity_with_invariants(name):
    """Full-attention configs: speculation over the paged cold tier
    matches contiguous non-speculative output, with the page-pool
    refcount census (including the rollback occupancy check) green
    after every loop iteration."""
    cfg, params = _setup(name)
    dcfg = spec_lib.make_draft_config(cfg)
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    prompts, _ = _inputs(cfg)
    base = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4)
    ref = np.asarray(base.generate(prompts, max_new_tokens=NEW).tokens)
    eng = Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                 paged=True, page_size=8,
                 draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    reqs = [Request(i, np.asarray(prompts)[i], NEW) for i in range(2)]
    fins = {f.rid: f for f in eng.serve(
        reqs, slots=2, on_iteration=check_serving_invariants)}
    for i in range(2):
        np.testing.assert_array_equal(ref[i], fins[i].tokens)
