"""Roofline analysis: HLO collective walker (trip counts) + ledger sanity."""

import pytest

from repro.analysis import roofline
from repro.configs import SHAPES, get_config

SYNTH_HLO = """
HloModule jit_step

%body_inner (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %ar = f32[4,8]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %ar)
}

%body_outer (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %w_in = (s32[], f32[4,8]) while(%t0), condition=%c1, body=%body_inner, backend_config={"known_trip_count":{"n":"6"}}
  %ag = f32[8,8]{1,0} all-gather(%y), dimensions={0}
  ROOT %t2 = (s32[], f32[4,8]) tuple(%j, %z)
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %w = (s32[], f32[4,8]) while(%init), condition=%c0, body=%body_outer, backend_config={"known_trip_count":{"n":"3"}}
  %cp = f32[2,2]{1,0} collective-permute(%b), source_target_pairs={{0,1}}
  ROOT %r = f32[4,8] get-tuple-element(%w), index=1
}
"""


def test_collective_walker_multiplies_trip_counts():
    out = roofline.collective_bytes_from_hlo(SYNTH_HLO)
    # all-reduce f32[4,8]=128B inside inner while: 3 (outer) * 6 (inner) = 18x
    # all-gather f32[8,8]=256B inside outer while: 3x
    # collective-permute f32[2,2]=16B at entry: 1x
    assert out["by_kind"]["all-reduce"] == 128 * 18
    assert out["by_kind"]["all-gather"] == 256 * 3
    assert out["by_kind"]["collective-permute"] == 16
    assert out["op_count"] == 18 + 3 + 1


def test_collective_walker_skips_done_halves():
    hlo = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %s = f32[16]{0} all-gather-start(%a), dimensions={0}
  %d = f32[16]{0} all-gather-done(%s)
  ROOT %r = f32[4] slice(%d)
}
"""
    out = roofline.collective_bytes_from_hlo(hlo)
    assert out["by_kind"]["all-gather"] == 64  # counted once


def test_model_flops_moe_counts_active_only():
    dense = get_config("qwen3-8b")
    moe = get_config("mixtral-8x22b")
    shape = SHAPES["train_4k"]
    act = roofline.active_params(moe)
    assert act < moe.param_count() * 0.35  # top-2 of 8 experts
    assert roofline.model_flops(dense, shape, "train") == pytest.approx(
        6.0 * dense.param_count() * shape.global_batch * shape.seq_len
    )


def test_analytic_terms_ordering():
    """Decode is memory/collective bound, train is compute>>memory — the
    ledger must reflect the regimes."""
    cfg = get_config("gemma-7b")
    tr = SHAPES["train_4k"]
    de = SHAPES["decode_32k"]
    f_train = roofline.analytic_flops(cfg, tr, "train")
    f_dec = roofline.analytic_flops(cfg, de, "decode")
    assert f_train > 1000 * f_dec
    b_dec = roofline.analytic_hbm_bytes(cfg, de, "decode")
    # decode arithmetic intensity is tiny (GEMV regime)
    assert f_dec / b_dec < 30.0


def test_kv_fp8_halves_cache_term():
    import dataclasses

    cfg = get_config("gemma-7b")
    cfg8 = dataclasses.replace(
        cfg, bitnet=dataclasses.replace(cfg.bitnet, kv_fp8=True)
    )
    de = SHAPES["decode_32k"]
    b16 = roofline.analytic_hbm_bytes(cfg, de, "decode")
    b8 = roofline.analytic_hbm_bytes(cfg8, de, "decode")
    assert b8 < 0.65 * b16  # cache dominates; halving it shows through
