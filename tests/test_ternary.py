"""Unit + property tests for BitNet b1.58 quantization (core/ternary.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ternary

jax.config.update("jax_enable_x64", False)


def test_weight_quant_values_are_ternary():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    q = ternary.weight_quant_absmean(w)
    assert q.wq.dtype == jnp.int8
    assert set(np.unique(np.asarray(q.wq))).issubset({-1, 0, 1})
    assert float(q.scale) == pytest.approx(float(jnp.mean(jnp.abs(w))), rel=1e-6)


def test_weight_quant_matches_bitnet_rule():
    """W_q must equal RoundClip(W / mean|W|, -1, 1) exactly."""
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 16)) * 0.02
    q = ternary.weight_quant_absmean(w)
    scale = np.mean(np.abs(np.asarray(w, dtype=np.float32)))
    expect = np.clip(np.round(np.asarray(w, np.float32) / scale), -1, 1)
    np.testing.assert_array_equal(np.asarray(q.wq), expect.astype(np.int8))


@pytest.mark.parametrize("bits,qmin,qmax", [(8, -128, 127), (4, -8, 7)])
def test_act_quant_range(bits, qmin, qmax):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256)) * 10
    q = ternary.act_quant(x, bits=bits)
    xq = np.asarray(q.xq)
    assert xq.min() >= qmin and xq.max() <= qmax
    # absmax element must map to +/- qmax
    assert np.max(np.abs(xq)) == qmax


def test_act_quant_dequant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512))
    q = ternary.act_quant(x, bits=8)
    xd = ternary.act_dequant(q)
    # max error bounded by half a quantization step per token
    step = 1.0 / np.asarray(q.scale)
    assert np.max(np.abs(np.asarray(xd - x)) - 0.5 * step) < 1e-5


def test_ste_identity_gradient():
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 8))
    g = jax.grad(lambda w: jnp.sum(ternary.weight_quant_ste(w) ** 2))(w)
    # STE: d/dw sum(q(w)^2) == 2*q(w) (identity through the quantizer)
    qw = ternary.weight_quant_ste(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * qw), rtol=1e-5, atol=1e-5)


def test_ternary_mac_is_mult_free_equivalent():
    key = jax.random.PRNGKey(5)
    xq = jax.random.randint(key, (4, 96), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(6), (96, 24), -1, 2, dtype=jnp.int8)
    acc = ternary.ternary_mac_reference(xq, wq)
    expect = np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    np.testing.assert_array_equal(np.asarray(acc, np.int64), expect)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 7),
    k=st.integers(1, 65),
    n=st.integers(1, 9),
    seed=st.integers(0, 2**30),
)
def test_property_mac_matches_integer_matmul(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    xq = jax.random.randint(kx, (m, k), -128, 128, dtype=jnp.int8)
    wq = jax.random.randint(kw, (k, n), -1, 2, dtype=jnp.int8)
    acc = ternary.ternary_mac_reference(xq, wq)
    np.testing.assert_array_equal(
        np.asarray(acc, np.int64), np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), bits=st.sampled_from([4, 8]))
def test_property_fake_quant_linear_close_to_float(seed, bits):
    """Fake-quant forward approximates the float matmul within quant error."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (3, 64))
    w = jax.random.normal(k2, (64, 16)) * 0.05
    y = ternary.fake_quant_linear(x, w, bits=bits)
    assert np.all(np.isfinite(np.asarray(y)))
    # scale of output should match float matmul within ~50% (coarse ternary)
    ref = x @ w
    denom = float(jnp.linalg.norm(ref)) + 1e-6
    rel = float(jnp.linalg.norm(y - ref)) / denom
    assert rel < 1.0  # sanity: quantization is lossy but not unbounded


def test_sparsity_measured():
    wq = jnp.array([[0, 1, -1, 0], [0, 0, 1, -1]], dtype=jnp.int8)
    assert float(ternary.ternary_sparsity(wq)) == pytest.approx(4 / 8)
