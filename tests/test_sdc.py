"""Silent-data-corruption resilience (ISSUE 10).

Layers, mirroring docs/serving.md "Fault model & SDC ladder":

  * **detection primitives** — ABFT row-sum checks over packed ternary
    leaves (clean weights never false-positive; one flipped bit is
    caught, incl. via the all-ones probe), crc32 weight verification,
    and the load-time golden-copy guard;
  * **containment plumbing** — page quarantine semantics in the pool
    (parked decrefs, census accounting, (page, born) life stamps),
    prefix-tree subtree eviction and flush;
  * **falsifiability** — every new invariant check is demonstrated to
    catch a hand-built violation (stranded quarantined pages, faked
    repair counters, fake fleet retirements) in the same call;
  * **the ladder end-to-end** — seeded ROM / retention / NaN chaos on
    the three fixed CI seeds: every detectable fault is detected within
    one scrub period and repaired, final greedy outputs are
    BIT-IDENTICAL to a faultless run, invariants green every iteration;
  * **fleet retirement** — repeated weight faults strike a replica out;
    the router drains and permanently retires it and the work finishes
    bit-exactly on the survivor;
  * **handoff byte-fuzz** — any mutation of a warm-migration payload
    either raises HandoffError or imports bit-identically (hypothesis
    property + an always-running seeded fallback).
"""

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core import bitlinear, kv_cache
from repro.core.bitlinear import AbftError
from repro.core.kv_cache import HandoffError, pack_slot_state, unpack_slot_state
from repro.models import pack as pack_lib
from repro.models import transformer as T
from repro.serving import sdc as sdc_lib
from repro.serving.chaos import (ChaosConfig, ChaosInjector,
                                 InvariantViolation, check_fleet_invariants,
                                 check_serving_invariants)
from repro.serving.engine import Engine, ServeStats
from repro.serving.paging import PagePool, PrefixCache
from repro.serving.replica import Replica
from repro.serving.router import Router, RouterStats
from repro.serving.scheduler import Request

HOT, ML, PS = 4, 64, 8
CI_SEEDS = [0, 1, 2]  # the fixed fast-lane seeds (.github/workflows/ci.yml)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    packed = pack_lib.add_integrity(pack_lib.pack_params(params, cfg))
    return cfg, params, packed


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


def _reqs(cfg, n=4, budget=12):
    return [Request(i, _prompt(400 + i, 6 + i, cfg.vocab_size), budget)
            for i in range(n)]


def _engine(cfg, params, integrity=None, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("sync_every", 2)
    return Engine(cfg, params, hot_cap=HOT, max_len=ML, prefill_chunk=4,
                  paged=True, page_size=PS, integrity=integrity, **kw)


# ---------------------------------------------------------------------------
# ABFT + crc detection primitives
# ---------------------------------------------------------------------------


def _first_leaves(packed, n=3):
    out = []
    for path, pw in pack_lib.iter_packed_leaves(packed):
        out.append((path, pw))
        if len(out) >= n:
            break
    return out


def test_abft_clean_weights_no_false_positive(setup):
    """Checked matmul on clean leaves: y matches the unchecked fast
    path bit-for-bit and no AbftError fires — across plain AND fused
    (per-column-scale) leaves, random activations."""
    cfg, params, packed = setup
    fused_seen = False
    for path, pw in pack_lib.iter_packed_leaves(packed):
        sub = next(iter(sdc_lib._leaf_slices(pw)))
        fused_seen |= np.ndim(sub.scale) == 1 and np.size(sub.scale) > 1
        x = np.asarray(jax.random.normal(
            jax.random.PRNGKey(hash(path) % 1000), (4, sub.k)), np.float32)
        y = bitlinear.packed_matmul_checked(sub, x)  # must not raise
        ref = bitlinear.packed_matmul(sub, x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    assert fused_seen  # the pack really produced fused per-column leaves


def test_abft_detects_single_trit_flip(setup):
    """One flipped bit in the packed words shifts a row-sum far outside
    the rounding tolerance: AbftError, carrying the offending row."""
    cfg, params, packed = setup
    path, pw = _first_leaves(packed, 1)[0]
    sub = next(iter(sdc_lib._leaf_slices(pw)))
    words = np.asarray(sub.packed).copy()
    words.reshape(-1)[3] ^= 1  # one stuck bit
    bad = dataclasses.replace(sub, packed=jnp.asarray(words))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (4, sub.k)),
                   np.float32)
    with pytest.raises(AbftError, match="row-sum mismatch") as ei:
        bitlinear.packed_matmul_checked(bad, x)
    assert ei.value.row is not None


def test_abft_verify_tree_probe_catches_any_flip(setup):
    """The all-ones probe (every input quantizes to qmax) sees every
    single-bit flip in every leaf; a clean tree reports nothing."""
    cfg, params, packed = setup
    assert sdc_lib.abft_verify_tree(packed) == []
    rng = np.random.default_rng(0)
    paths = [p for p, _ in pack_lib.iter_packed_leaves(packed)]
    for path in (paths[0], paths[len(paths) // 2], paths[-1]):
        pw = sdc_lib.get_leaf(packed, path)
        idx = int(rng.integers(np.asarray(pw.packed).size))
        bit = int(rng.integers(8))
        flipped = sdc_lib.flip_packed_bit(packed, path, idx, bit)
        assert path in sdc_lib.abft_verify_tree(flipped)


def test_crc_verify_and_flip_preserve_avals(setup):
    """flip_packed_bit mutates exactly one packed word (same shape,
    same dtype — no recompile) and verify_packed names exactly the
    damaged leaf; the crc is exact, so even a flip ABFT could miss
    (zero-activation blind spot) is caught."""
    cfg, params, packed = setup
    assert pack_lib.verify_packed(packed) == []
    path, pw = _first_leaves(packed, 1)[0]
    flipped = sdc_lib.flip_packed_bit(packed, path, 11, 5)
    npw = sdc_lib.get_leaf(flipped, path)
    assert npw.packed.shape == pw.packed.shape
    assert npw.packed.dtype == pw.packed.dtype
    diff = np.asarray(npw.packed) != np.asarray(pw.packed)
    assert diff.sum() == 1
    assert pack_lib.verify_packed(flipped) == [path]


def test_engine_refuses_corrupt_weights_at_load(setup):
    """The load-time crc gate: pre-packed weights that fail
    verification never serve a token."""
    cfg, params, packed = setup
    path, _ = _first_leaves(packed, 1)[0]
    corrupt = sdc_lib.flip_packed_bit(packed, path, 0, 0)
    with pytest.raises(sdc_lib.WeightFaultError, match="crc32 at load"):
        _engine(cfg, corrupt, pack=False,
                integrity=sdc_lib.IntegrityConfig())


# ---------------------------------------------------------------------------
# quarantine pool semantics + prefix-tree containment
# ---------------------------------------------------------------------------


def test_pool_born_stamps_one_life_per_allocation():
    pool = PagePool(4)
    [p] = pool.alloc(1)
    first = int(pool.born[p])
    pool.decref([p])
    [q] = pool.alloc(1)
    assert q == p  # same physical page...
    assert int(pool.born[q]) > first  # ...new life


def test_quarantine_free_and_referenced_pages():
    pool = PagePool(4)
    free_page = pool._free[0]
    pool.quarantine(free_page)  # free page: leaves the free list now
    assert free_page not in pool._free
    [held] = pool.alloc(1)
    pool.quarantine(held)  # referenced page: parks at final decref
    assert pool.refs[held] == 1
    pool.decref([held])
    assert pool.refs[held] == 0
    assert held not in pool._free  # parked, not recycled
    assert pool.quarantined == {free_page, held}
    # census: free + used partition excludes the quarantined for good
    assert pool.used() == 0
    assert pool.available() == pool.n_pages - 2
    pool.quarantine(held)  # idempotent
    assert len(pool.quarantined) == 2


def test_evict_pages_cuts_damaged_subtree_and_flush_drops_all():
    pool = PagePool(8)
    tree = PrefixCache(pool, hot_cap=2, page_size=2)
    toks = np.asarray([1, 2, 3, 4, 5, 6], np.int32)
    pages = pool.alloc(2)
    assert tree.insert(toks, pages, lambda ids: None)
    held = set(tree.tree_pages())
    # the tree adopts both full cold pages (increfed: one physical copy,
    # two readers) and snapshots the hot tier into one fresh page
    assert set(pages) <= held and len(held) == 3
    assert all(pool.refs[p] == 2 for p in pages)
    tree.evict_pages([pages[0]])  # damage the first page: subtree goes
    assert pages[0] not in set(tree.tree_pages())
    tree.flush()
    assert tree.tree_pages() == []
    pool.decref(pages)  # drop the slot's own reader refs
    for p in range(pool.n_pages):
        assert pool.refs[p] == 0


# ---------------------------------------------------------------------------
# falsifiability: the new checks catch hand-built violations
# ---------------------------------------------------------------------------


def _fake_ctx(pool, tree=None, slot_pages=(), stats=None):
    return SimpleNamespace(
        pool=pool, ptree=tree,
        sched=SimpleNamespace(slot_req=[object()] * len(slot_pages)),
        slot_pages=[list(p) for p in slot_pages],
        host_table=None, stats=stats or ServeStats(),
    )


def test_checker_catches_quarantined_page_on_free_list():
    pool = PagePool(4)
    p = pool._free[0]
    pool.quarantined.add(p)  # corrupt directly: quarantine() delists
    with pytest.raises(InvariantViolation, match="free list"):
        check_serving_invariants(_fake_ctx(pool))


def test_checker_catches_quarantined_page_still_mapped():
    pool = PagePool(4)
    [p] = pool.alloc(1)
    pool.quarantined.add(p)
    with pytest.raises(InvariantViolation, match="still mapped by slot"):
        check_serving_invariants(_fake_ctx(pool, slot_pages=[[p]]))


def test_checker_catches_quarantined_page_in_tree():
    pool = PagePool(8)
    tree = PrefixCache(pool, hot_cap=2, page_size=2)
    assert tree.insert(np.asarray([1, 2, 3], np.int32), [], lambda ids: None)
    [hot_page] = tree.tree_pages()  # the hot-tier snapshot page
    pool.quarantined.add(hot_page)
    with pytest.raises(InvariantViolation, match="prefix tree"):
        check_serving_invariants(_fake_ctx(pool, tree=tree))


def test_checker_catches_faked_repair_counters():
    """Check 9: each repair counter is bounded by its injection budget;
    a counter above it means the scrub invented a fault."""
    budget = dict(weight_asserts=1, page_flips=1, nan_pokes=1)
    pool = PagePool(4)
    ctx = _fake_ctx(pool, stats=ServeStats(weight_reloads=2))
    with pytest.raises(InvariantViolation, match="weight_reloads"):
        check_serving_invariants(ctx, sdc_budget=budget)
    pool2 = PagePool(4)
    q = pool2._free.pop()  # delist so only the census check below fires
    pool2.quarantined.update({q, 0 if q else 1})
    pool2._free.remove(0 if q else 1)
    ctx2 = _fake_ctx(pool2)
    with pytest.raises(InvariantViolation, match="quarantined pages exceed"):
        check_serving_invariants(ctx2, sdc_budget=budget)
    ctx3 = _fake_ctx(PagePool(4), stats=ServeStats(slots_quarantined=2))
    with pytest.raises(InvariantViolation, match="slots_quarantined"):
        check_serving_invariants(ctx3, sdc_budget=budget)
    ctx4 = _fake_ctx(PagePool(4), stats=ServeStats(sdc_detected=4))
    with pytest.raises(InvariantViolation, match="sdc_detected"):
        check_serving_invariants(ctx4, sdc_budget=budget)
    # and the clean configuration passes with the same budget
    check_serving_invariants(_fake_ctx(PagePool(4)), sdc_budget=budget)


def _fake_router(**kw):
    r = SimpleNamespace(
        finished=[], pending=[], replicas={}, accepted={}, assigned={},
        attempts={}, stats=RouterStats(), _retired=set(), _sdc_retired=set(),
    )
    for k, v in kw.items():
        setattr(r, k, v)
    return r


def test_fleet_checker_catches_fake_sdc_retirement():
    """Check 6: the retirement counter must match the retired set, and
    an SDC-retired replica must stay permanently dead."""
    r = _fake_router(stats=RouterStats(sdc_retirements=1))
    with pytest.raises(InvariantViolation, match="sdc_retirements"):
        check_fleet_invariants(r)
    rep = SimpleNamespace(name="x", dead=False, ctx=None,
                          engine=SimpleNamespace(unhealthy=True))
    r2 = _fake_router(stats=RouterStats(sdc_retirements=1),
                      replicas={"x": rep}, _retired={"x"},
                      _sdc_retired={"x"})
    with pytest.raises(InvariantViolation, match="not permanently dead"):
        check_fleet_invariants(r2)  # resurrected: not dead
    rep.dead = True
    rep.engine.unhealthy = False
    with pytest.raises(InvariantViolation, match="not permanently dead"):
        check_fleet_invariants(r2)  # engine no longer flagged
    rep.engine.unhealthy = True
    check_fleet_invariants(r2)  # consistent retirement passes


# ---------------------------------------------------------------------------
# the ladder, single faults: detect -> contain -> repair
# ---------------------------------------------------------------------------


def test_weight_fault_detected_repaired_within_one_scrub_period(setup):
    """A stuck ROM bit planted mid-decode is caught by the next scrub
    (crc + ABFT probe), reloaded from the golden copy, every slot rolls
    back to its verified frontier, and the final greedy outputs are
    bit-identical to the faultless run."""
    cfg, params, _ = setup
    reqs = _reqs(cfg)
    ref = {f.rid: f.tokens for f in _engine(cfg, params).serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs])}

    scrub_every = 2
    eng = _engine(cfg, params,
                  integrity=sdc_lib.IntegrityConfig(scrub_every=scrub_every))
    planted_at, detected_at = [], []

    def hook(ctx):
        if ctx.iteration == 2 and not planted_at:
            path = next(iter(pack_lib.iter_packed_leaves(eng.params)))[0]
            eng.params = sdc_lib.flip_packed_bit(eng.params, path, 5, 2)
            planted_at.append(ctx.iteration)
        if ctx.stats.weight_reloads and not detected_at:
            detected_at.append(ctx.iteration)

    ctx = eng.start_session(reqs, on_iteration=hook)
    while eng.run_iteration(ctx):
        pass
    assert planted_at and detected_at
    assert detected_at[0] <= planted_at[0] + scrub_every
    assert ctx.stats.weight_reloads == 1
    assert eng.weight_fault_strikes == 1
    assert pack_lib.verify_packed(eng.params) == []  # golden copy restored
    for f in ctx.finished:
        assert f.outcome == "finished"
        np.testing.assert_array_equal(f.tokens, ref[f.rid])
    eng.finish_session(ctx)


def test_hand_corrupted_page_is_quarantined_and_rolled_back(setup):
    """Flip a bit in a crc-stamped cold page through the pool's own
    gather/write surface: the scrub quarantines the page for good,
    evicts it from every reader and recompute stays bit-identical."""
    cfg, params, _ = setup
    reqs = _reqs(cfg, n=2, budget=20)  # long decode: cold pages fill
    ref = {f.rid: f.tokens for f in _engine(cfg, params).serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs])}

    eng = _engine(cfg, params,
                  integrity=sdc_lib.IntegrityConfig(scrub_every=1))
    flipped = []

    def hook(ctx):
        if flipped or not ctx.page_crc:
            return
        page = sorted(ctx.page_crc)[0]
        key = next(k for k in sorted(ctx.state.cache)
                   if hasattr(ctx.state.cache[k], "page_table"))
        cache = ctx.state.cache[key]
        kp, vp = kv_cache.gather_pool_pages(cache, [page])
        raw = bytearray(np.ascontiguousarray(kp).tobytes())
        raw[0] ^= 0x10
        kp = np.frombuffer(bytes(raw), dtype=kp.dtype).reshape(kp.shape)
        caches = dict(ctx.state.cache)
        caches[key] = kv_cache.write_pool_pages(cache, [page], kp, vp)
        ctx.state = ctx.state._replace(cache=caches)
        flipped.append(page)

    ctx = eng.start_session(reqs, on_iteration=hook)
    while eng.run_iteration(ctx):
        pass
    assert flipped
    assert set(flipped) <= ctx.pool.quarantined
    assert ctx.stats.sdc_detected >= 1
    for f in ctx.finished:
        assert f.outcome == "finished"
        np.testing.assert_array_equal(f.tokens, ref[f.rid])
    check_serving_invariants(ctx, sdc_budget=dict(page_flips=len(flipped)))
    eng.finish_session(ctx)


def test_numerics_containment_and_transient_repair(setup):
    """One NaN upset: the poked slot terminates with outcome
    ``numerics`` (partial output surfaced, not retried), the poison is
    scrubbed out of the hot tier and the slot's pages, and every other
    request finishes bit-identically — 1 poke, exactly 1 containment."""
    cfg, params, _ = setup
    reqs = _reqs(cfg)
    ref = {f.rid: f.tokens for f in _engine(cfg, params).serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs])}

    eng = _engine(cfg, params,
                  integrity=sdc_lib.IntegrityConfig(scrub_every=2))
    poked = []

    def hook(ctx):
        if poked:
            return
        decoding = [s for s in ctx.sched.active_slots()
                    if s not in ctx.prefilling]
        if decoding and sdc_lib.inject_activation_nan(ctx, decoding[0]):
            poked.append(ctx.sched.slot_req[decoding[0]].rid)

    ctx = eng.start_session(reqs, on_iteration=hook)
    while eng.run_iteration(ctx):
        pass
    assert len(poked) == 1
    outcomes = {f.rid: f.outcome for f in ctx.finished}
    assert outcomes[poked[0]] == "numerics"
    assert ctx.stats.slots_quarantined == 1
    for f in ctx.finished:
        if f.outcome == "finished":
            np.testing.assert_array_equal(f.tokens, ref[f.rid])
    check_serving_invariants(ctx, sdc_budget=dict(nan_pokes=1))
    eng.finish_session(ctx)


def test_numerics_raise_mode_names_the_slot(setup):
    cfg, params, _ = setup
    eng = _engine(cfg, params, integrity=sdc_lib.IntegrityConfig(
        scrub_every=1, on_numerics="raise"))
    state = {"armed": True}

    def hook(ctx):
        if not state["armed"]:
            return
        decoding = [s for s in ctx.sched.active_slots()
                    if s not in ctx.prefilling]
        if decoding and sdc_lib.inject_activation_nan(ctx, decoding[0]):
            state["armed"] = False

    ctx = eng.start_session(_reqs(cfg), on_iteration=hook)
    with pytest.raises(sdc_lib.NumericsError) as ei:
        while eng.run_iteration(ctx):
            pass
    assert ei.value.slot is not None


# ---------------------------------------------------------------------------
# the ladder end-to-end: seeded chaos, three CI seeds
# ---------------------------------------------------------------------------


def _sdc_chaos_serve(cfg, params, seed):
    reqs = _reqs(cfg, n=5)
    eng = _engine(cfg, params, integrity=sdc_lib.IntegrityConfig(
        scrub_every=2, max_weight_strikes=10 ** 6))
    chaos = ChaosInjector(eng, ChaosConfig(
        seed=seed, weight_flip_rate=0.2, page_decay_rate=0.1, nan_rate=0.1))
    ctx = eng.start_session(reqs, on_iteration=chaos.on_iteration)
    while eng.run_iteration(ctx):
        pass
    chaos.release_all(ctx)
    check_serving_invariants(ctx, sdc_budget=chaos.sdc_budget())
    return reqs, eng, chaos, ctx


@pytest.mark.parametrize("seed", CI_SEEDS)
def test_sdc_chaos_serve_stays_bit_exact(setup, seed):
    """All three fault planes at once, invariants checked inside the
    hook every iteration: finished requests are bit-identical to a
    faultless run, NaN containments name injected pokes only, and the
    detection ledger reconciles exactly — every detection is a weight
    reload, a quarantined page or a contained slot."""
    cfg, params, _ = setup
    reqs, eng, chaos, ctx = _sdc_chaos_serve(cfg, params, seed)
    fin = {f.rid: f for f in ctx.finished}
    assert sorted(fin) == [r.rid for r in reqs]
    assert {f.outcome for f in ctx.finished} <= {"finished", "numerics"}
    # rebuild pristine prompts: the engine rewrites Request.tokens to the
    # generated stream, so the processed objects can't seed the reference
    ref_eng = _engine(cfg, params)
    ref = {f.rid: f for f in ref_eng.serve(_reqs(cfg, n=5))}
    for f in ctx.finished:
        if f.outcome == "finished":
            np.testing.assert_array_equal(f.tokens, ref[f.rid].tokens)
    st = ctx.stats
    assert st.sdc_detected == (st.weight_reloads
                               + len(ctx.pool.quarantined)
                               + st.slots_quarantined)
    assert st.slots_quarantined <= chaos.nan_pokes
    eng.finish_session(ctx)


def test_sdc_chaos_is_deterministic_per_seed(setup):
    cfg, params, _ = setup
    _, _, chaos_a, ctx_a = _sdc_chaos_serve(cfg, params, seed=1)
    out_a = sorted((f.rid, f.outcome, len(f.tokens)) for f in ctx_a.finished)
    stats_a = (ctx_a.stats.sdc_detected, ctx_a.stats.weight_reloads,
               ctx_a.stats.slots_quarantined, chaos_a.sdc_budget())
    _, _, chaos_b, ctx_b = _sdc_chaos_serve(cfg, params, seed=1)
    out_b = sorted((f.rid, f.outcome, len(f.tokens)) for f in ctx_b.finished)
    stats_b = (ctx_b.stats.sdc_detected, ctx_b.stats.weight_reloads,
               ctx_b.stats.slots_quarantined, chaos_b.sdc_budget())
    assert out_a == out_b
    assert stats_a == stats_b


# ---------------------------------------------------------------------------
# fleet: strikes -> unhealthy -> drain + permanent retirement
# ---------------------------------------------------------------------------


def test_repeated_weight_faults_retire_replica_work_survives(setup):
    """A persistent stuck ROM bank on one replica: the engine strikes
    out, the router warm-migrates its work and retires it permanently
    (fleet check 6), and every request finishes bit-identically on the
    survivor."""
    cfg, params, _ = setup
    reqs = _reqs(cfg)
    ref = {f.rid: f.tokens for f in _engine(cfg, params).serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs])}

    def mk(strikes):
        return _engine(cfg, params, integrity=sdc_lib.IntegrityConfig(
            scrub_every=2, max_weight_strikes=strikes))

    reps = [Replica("a", mk(2)), Replica("b", mk(10 ** 6))]
    router = Router(reps, seed=0)
    rom = sdc_lib.RomFaultInjector(7, rate=1.0, reassert=None)

    def on_tick(r):
        a = r.replicas["a"]
        if not a.dead and a.ctx is not None:
            rom.on_iteration(a.engine, a.ctx)
        check_fleet_invariants(r)

    fin = {f.rid: f for f in router.serve(
        [Request(r.rid, r.tokens, r.max_new_tokens) for r in reqs],
        on_tick=on_tick)}
    check_fleet_invariants(router)
    assert router.stats.sdc_retirements == 1
    assert router._sdc_retired == {"a"}
    assert reps[0].dead and reps[0].engine.unhealthy
    assert not reps[1].dead
    for rid, want in ref.items():
        assert fin[rid].outcome == "finished"
        np.testing.assert_array_equal(fin[rid].tokens, want)


# ---------------------------------------------------------------------------
# handoff byte-fuzz: detect-or-identical, never silent corruption
# ---------------------------------------------------------------------------


def _handoff_states(seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: rng.standard_normal(s).astype(np.float32)  # noqa: E731
    return {
        "attn": {
            "length": 11, "stacked": True,
            "hot_k": mk(2, 4, 2, 8), "hot_v": mk(2, 4, 2, 8),
            "cold_k": mk(2, 7, 2, 8), "cold_v": mk(2, 7, 2, 8),
        },
    }


def _states_equal(a, b):
    if sorted(a) != sorted(b):
        return False
    for k in a:
        for f in ("length", "stacked"):
            if a[k][f] != b[k][f]:
                return False
        for f in ("hot_k", "hot_v", "cold_k", "cold_v"):
            x, y = np.asarray(a[k][f]), np.asarray(b[k][f])
            if x.shape != y.shape or x.tobytes() != y.tobytes():
                return False
    return True


def _assert_detect_or_identical(payload, mutated, states):
    if mutated == payload:
        return
    try:
        got = unpack_slot_state(mutated)
    except HandoffError:
        return  # detected: the receiver falls back to cold recompute
    assert _states_equal(got, states), \
        "mutated handoff imported DIFFERENT state without an error"


@given(st.integers(min_value=0, max_value=10 ** 9),
       st.integers(min_value=1, max_value=255))
@settings(max_examples=60, deadline=None)
def test_handoff_byte_flip_property(pos_seed, xor):
    """Property: flipping any byte of a handoff payload either raises
    HandoffError or the import is bit-identical — never silently
    different KV state."""
    states = _handoff_states()
    payload = pack_slot_state(states, page_size=4)
    pos = pos_seed % len(payload)
    mutated = bytearray(payload)
    mutated[pos] ^= xor
    _assert_detect_or_identical(payload, bytes(mutated), states)


def test_handoff_fuzz_fixed_seeds():
    """Always-running fallback for bare environments (the hypothesis
    test above skips without the package): seeded byte flips at every
    region of the frame — magic, header, dtype names, page chunks,
    page crcs, whole-payload trailer — plus torn truncations."""
    states = _handoff_states()
    payload = pack_slot_state(states, page_size=4)
    assert _states_equal(unpack_slot_state(payload), states)  # round-trip
    for seed in CI_SEEDS:
        rng = np.random.default_rng(seed)
        for _ in range(60):
            mutated = bytearray(payload)
            mutated[int(rng.integers(len(payload)))] ^= int(
                rng.integers(1, 256))
            _assert_detect_or_identical(payload, bytes(mutated), states)
        for _ in range(20):  # torn transfers
            cut = int(rng.integers(1, len(payload)))
            with pytest.raises(HandoffError):
                unpack_slot_state(payload[:cut])
