"""Graceful degradation under KV-page pressure (ISSUE 7).

The acceptance scenario plus the satellite behaviours:

  * **overload e2e**: a pool sized ~1/4 of the workload's peak page
    demand serves every request to completion via LRU tree eviction +
    preemption-with-recompute; greedy tokens are BIT-IDENTICAL to an
    unconstrained run, the refcount-protocol invariant checker
    (``serving/chaos.py``) is green after every engine-loop iteration,
    and the pool's fatal-exhaustion error is never reached;
  * **lazy growth**: admission funds prompt pages only, decode growth is
    funded chunk-by-chunk (``ServeStats.grown_pages`` reconciles with
    the closed-form page count);
  * **deadlines / cancellation / backpressure**: terminal outcomes
    (``expired`` / ``cancelled`` / ``rejected``) for queued AND active
    requests, partial tokens surfaced, pages always returned;
  * **feasibility validation**: a request that cannot fit the pool even
    with every other slot preempted is refused up front (ValueError),
    which is what makes the PagePoolError path unreachable under the
    default policy;
  * **property fuzz**: seeded random op sequences against the host
    control plane (PagePool + PrefixCache + slot lifecycles) with the
    invariant checker run after every op — plus a hypothesis-driven
    variant when ``.[property]`` is installed.
"""

import random
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.chaos import InvariantViolation, check_serving_invariants
from repro.serving.engine import Engine
from repro.serving.paging import PagePool, PrefixCache
from repro.serving.scheduler import Request

HOT, ML, PS = 4, 64, 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("falcon3-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(seed, n, vocab):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab), np.int32
    )


def _mk(reqs, **kw):
    return [Request(r.rid, r.tokens, r.max_new_tokens, **kw) for r in reqs]


def _paged_engine(cfg, params, **kw):
    kw.setdefault("hot_cap", HOT)
    kw.setdefault("max_len", ML)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("page_size", PS)
    return Engine(cfg, params, paged=True, **kw)


def _tree_only(eng):
    """Assert the pool's only remaining readers are prefix-tree pages."""
    pool, tree = eng._last_pool, eng._last_ptree
    tp = set(tree.tree_pages())
    for p in range(pool.n_pages):
        assert pool.refs[p] == (1 if p in tp else 0), p
    assert pool.available() == pool.n_pages - len(tp)


# ---------------------------------------------------------------------------
# acceptance: overload completes, bit-exact, invariants green every iteration
# ---------------------------------------------------------------------------


def test_overload_preempts_and_completes_bit_exact(setup):
    """Pool of 5 pages vs a peak demand of 8 (two slots × 4 pages):
    requests finish through eviction + preemption + recompute-from-
    prefix, tokens bit-identical to the unconstrained run, and the
    refcount protocol holds after EVERY loop iteration."""
    cfg, params = setup
    reqs = [Request(i, _prompt(100 + i, 10 + i, cfg.vocab_size), 20)
            for i in range(4)]

    big = _paged_engine(cfg, params, slots=2)  # default (ample) pool
    fin_big = {f.rid: f for f in big.serve(_mk(reqs), slots=2, sync_every=4)}
    assert big.last_stats.preemptions == 0

    small = _paged_engine(cfg, params, slots=2, n_pages=5)
    fin = {f.rid: f for f in small.serve(
        _mk(reqs), slots=2, sync_every=4,
        on_iteration=check_serving_invariants,  # green every iteration
    )}
    stats = small.last_stats
    assert set(fin) == {0, 1, 2, 3}
    # degradation actually happened — and was survived
    assert stats.preemptions > 0
    assert stats.recompute_tokens > 0
    assert sum(f.n_preemptions for f in fin.values()) == stats.preemptions
    for r in reqs:
        assert fin[r.rid].outcome == "finished"
        assert fin[r.rid].prompt_len == r.prompt_len
        np.testing.assert_array_equal(fin[r.rid].tokens, fin_big[r.rid].tokens)
        assert len(fin[r.rid].tokens) == r.max_new_tokens
    # all slots retired: every non-tree page returned to the free list
    _tree_only(small)
    # preemption re-admissions ride the prefix cache: some recompute was
    # avoided (reuse observed), and what was recomputed is bounded by
    # the tokens the preempted attempts had actually cached
    assert any(f.prefix_tokens_reused > 0 for f in fin.values())


def test_lazy_growth_allocates_pages_on_demand(setup):
    """Admission funds only the prompt's pages; decode growth arrives
    chunk-by-chunk and totals exactly peak − prompt pages."""
    cfg, params = setup
    eng = _paged_engine(cfg, params, slots=1)
    p_len, m_new = 6, 30
    [f] = eng.serve([Request(0, _prompt(7, p_len, cfg.vocab_size), m_new)],
                    slots=1, sync_every=4,
                    on_iteration=check_serving_invariants)
    assert f.outcome == "finished" and len(f.tokens) == m_new
    prompt_pages = -(-max(p_len - HOT, 0) // PS)
    peak_pages = -(-max(p_len + m_new - HOT, 0) // PS)
    assert eng.last_stats.grown_pages == peak_pages - prompt_pages
    assert eng.last_stats.preemptions == 0
    _tree_only(eng)


# ---------------------------------------------------------------------------
# outcomes: deadlines, cancellation, backpressure, feasibility
# ---------------------------------------------------------------------------


def test_deadline_expires_queued_request(setup):
    """A queued request whose deadline passes (injected fake clock) is
    shed with outcome 'expired' and zero tokens; the running request is
    untouched and bit-exact."""
    cfg, params = setup
    clk = [0.0]
    eng = _paged_engine(cfg, params, slots=1, clock=lambda: clk[0])
    reqs = [Request(0, _prompt(20, 8, cfg.vocab_size), 12),
            Request(1, _prompt(21, 8, cfg.vocab_size), 12, deadline=1.0)]

    def advance(ctx):
        if ctx.iteration >= 1:
            clk[0] = 2.0

    fin = {f.rid: f for f in eng.serve(reqs, slots=1, sync_every=4,
                                       on_iteration=advance)}
    assert fin[1].outcome == "expired" and len(fin[1].tokens) == 0
    assert fin[0].outcome == "finished"
    assert eng.last_stats.expired == 1
    solo = _paged_engine(cfg, params, slots=1)
    [ref] = solo.serve([Request(9, reqs[0].tokens, 12)], slots=1)
    np.testing.assert_array_equal(fin[0].tokens, ref.tokens)
    _tree_only(eng)


def test_deadline_expires_active_request_with_partial_tokens(setup):
    """An ACTIVE slot whose deadline passes mid-decode retires at the
    next sync point, surfacing the tokens emitted so far (a prefix of
    the unconstrained generation) and freeing its pages."""
    cfg, params = setup
    clk = [0.0]
    eng = _paged_engine(cfg, params, slots=1, clock=lambda: clk[0])
    req = Request(0, _prompt(22, 8, cfg.vocab_size), 16, deadline=1.0)

    def advance(ctx):
        if ctx.iteration >= 1:
            clk[0] = 5.0

    [f] = eng.serve([req], slots=1, sync_every=4, on_iteration=advance)
    assert f.outcome == "expired"
    assert 0 < len(f.tokens) < 16
    assert f.steps == len(f.tokens)
    solo = _paged_engine(cfg, params, slots=1)
    [ref] = solo.serve([Request(9, req.tokens, 16)], slots=1)
    np.testing.assert_array_equal(f.tokens, ref.tokens[: len(f.tokens)])
    assert eng.last_stats.expired == 1
    _tree_only(eng)


def test_cancel_mid_decode_and_queued(setup):
    """``Engine.cancel`` propagates at the next sync point: an active
    slot surfaces its partial tokens and frees its pages; a queued rid
    never runs; unknown rids are no-ops; the bystander is bit-exact."""
    cfg, params = setup
    eng = _paged_engine(cfg, params, slots=2)
    reqs = [Request(i, _prompt(30 + i, 8, cfg.vocab_size), 14)
            for i in range(3)]  # slots=2 -> rid 2 starts queued

    def hook(ctx):
        if ctx.iteration == 0:
            eng.cancel(0)   # active (decoding) by the end of iteration 0
            eng.cancel(2)   # still queued behind the two slots
            eng.cancel(99)  # unknown rid: no-op

    fin = {f.rid: f for f in eng.serve(_mk(reqs), slots=2, sync_every=4,
                                       on_iteration=hook)}
    assert fin[0].outcome == "cancelled" and 0 < len(fin[0].tokens) < 14
    assert fin[2].outcome == "cancelled" and len(fin[2].tokens) == 0
    assert fin[1].outcome == "finished" and len(fin[1].tokens) == 14
    assert eng.last_stats.cancelled == 2
    solo = _paged_engine(cfg, params, slots=1)
    for rid in (0, 1):
        [ref] = solo.serve([Request(9, reqs[rid].tokens, 14)], slots=1)
        np.testing.assert_array_equal(
            fin[rid].tokens, ref.tokens[: len(fin[rid].tokens)])
    _tree_only(eng)


def test_cancel_mid_prefill_releases_everything(setup):
    """Cancellation landing while the prompt is still chunk-streaming
    (the hardest teardown path): no tokens, pages freed, protocol
    invariants intact."""
    cfg, params = setup
    eng = _paged_engine(cfg, params, slots=1)
    long_req = Request(0, _prompt(40, 30, cfg.vocab_size), 8)

    def hook(ctx):
        if ctx.iteration == 0:
            assert 0 in ctx.prefilling  # 30 tokens / chunk 4 > one wave
            eng.cancel(0)
        check_serving_invariants(ctx)

    [f] = eng.serve([long_req], slots=1, sync_every=2, on_iteration=hook)
    assert f.outcome == "cancelled" and len(f.tokens) == 0
    _tree_only(eng)


def test_bounded_queue_sheds_rejected(setup):
    """``max_queue`` bounds admission: overflow sheds with outcome
    'rejected' (zero work), accepted requests are unaffected."""
    cfg, params = setup
    eng = _paged_engine(cfg, params, slots=1, max_queue=2)
    reqs = [Request(i, _prompt(50 + i, 8, cfg.vocab_size), 6)
            for i in range(5)]
    fin = {f.rid: f for f in eng.serve(_mk(reqs), slots=1, sync_every=4)}
    outcomes = {rid: f.outcome for rid, f in fin.items()}
    assert outcomes == {0: "finished", 1: "finished", 2: "rejected",
                        3: "rejected", 4: "rejected"}
    assert eng.last_stats.rejected == 3
    for rid in (2, 3, 4):
        assert len(fin[rid].tokens) == 0 and fin[rid].steps == 0
    # per-call override relaxes the bound
    fin2 = eng.serve(_mk(reqs), slots=1, sync_every=4, max_queue=16)
    assert all(f.outcome == "finished" for f in fin2)


def test_unservable_request_refused_up_front(setup):
    """A request whose PEAK page demand exceeds the whole pool can never
    complete — refused at validation (this is what makes the runtime
    pool-exhausted error unreachable under the default policy)."""
    cfg, params = setup
    eng = _paged_engine(cfg, params, slots=2, n_pages=5)
    bad = Request(0, _prompt(60, 8, cfg.vocab_size), 52)  # peak 7 > 5
    with pytest.raises(ValueError, match="unservable"):
        eng.serve([bad], slots=2)
    # the same request against the default pool sizing is fine
    eng2 = _paged_engine(cfg, params, slots=2)
    [f] = eng2.serve([Request(0, bad.tokens, bad.max_new_tokens)], slots=2)
    assert f.outcome == "finished"


def test_priority_preempts_weaker_active_slot(setup):
    """A high-priority late arrival claims pages from a running
    lower-priority slot when the pool cannot hold both; the victim
    still completes (recompute) and both are bit-exact."""
    cfg, params = setup
    reqs = [Request(0, _prompt(70, 12, cfg.vocab_size), 20),
            Request(1, _prompt(71, 12, cfg.vocab_size), 20, priority=5)]
    big = _paged_engine(cfg, params, slots=2)
    fin_big = {f.rid: f for f in big.serve(_mk(reqs[:1]) + [
        Request(1, reqs[1].tokens, 20, priority=5)], slots=2)}
    small = _paged_engine(cfg, params, slots=2, n_pages=5)
    fin = {f.rid: f for f in small.serve(
        _mk(reqs[:1]) + [Request(1, reqs[1].tokens, 20, priority=5)],
        slots=2, sync_every=4, on_iteration=check_serving_invariants)}
    assert small.last_stats.preemptions > 0
    # the weak rid 0 was the (only possible) victim; both finished
    assert fin[0].n_preemptions > 0 and fin[1].n_preemptions == 0
    for rid in (0, 1):
        assert fin[rid].outcome == "finished"
        np.testing.assert_array_equal(fin[rid].tokens, fin_big[rid].tokens)
    _tree_only(small)


# ---------------------------------------------------------------------------
# property fuzz: host control plane under random op sequences
# ---------------------------------------------------------------------------


def _fuzz_control_plane(seed, steps=150):
    """Random admit/adopt/retire/evict/match sequences against PagePool +
    PrefixCache with the full invariant checker after every op. Mirrors
    the engine's bookkeeping: fresh pages born with the slot as reader,
    shared pages increfed on adoption, tree increfs on insert, slot
    decref on retire."""
    rng = random.Random(seed)
    hc, ps, n_pages, vocab = 4, 4, 20, 40
    pool = PagePool(n_pages)
    tree = PrefixCache(pool, hot_cap=hc, page_size=ps)
    slots = {}  # sid -> page list
    prompts = []  # history, so matches actually hit
    next_sid = [0]

    def ctx():
        live = sorted(slots)
        return SimpleNamespace(
            pool=pool, ptree=tree,
            sched=SimpleNamespace(slot_req=[object()] * len(live)),
            slot_pages=[slots[s] for s in live],
            host_table=None,
        )

    def rand_prompt():
        if prompts and rng.random() < 0.5:
            base = prompts[rng.randrange(len(prompts))]
            cut = rng.randrange(1, len(base) + 1)
            ext = [rng.randrange(vocab)
                   for _ in range(rng.randrange(0, 2 * ps))]
            toks = np.asarray(list(base[:cut]) + ext, np.int32)
        else:
            n = rng.randrange(1, hc + 4 * ps)
            toks = np.asarray([rng.randrange(vocab) for _ in range(n)],
                              np.int32)
        return toks

    def admit():
        toks = rand_prompt()
        m = tree.match(toks)
        shared = list(m.shared_pages)
        if shared:
            pool.incref(shared)  # the slot becomes a reader
        n_cold = -(-max(len(toks) - hc, 0) // ps)
        tree.evict_for(n_cold - len(shared))
        fresh = pool.alloc(n_cold - len(shared))
        if fresh is None:
            if shared:
                pool.decref(shared)  # unwind, like _admit_paged
            return
        sid = next_sid[0]
        next_sid[0] += 1
        slots[sid] = shared + fresh
        prompts.append(tuple(int(t) for t in toks))
        tree.insert(toks, slots[sid], lambda ids: None)

    def retire():
        if not slots:
            return
        sid = rng.choice(sorted(slots))
        pool.decref(slots.pop(sid))

    def evict():
        tree.evict_for(rng.randrange(0, n_pages + 1))

    def match():
        tree.match(rand_prompt())

    ops = [admit, admit, retire, evict, match]
    for _ in range(steps):
        rng.choice(ops)()
        check_serving_invariants(ctx())
    # drain: every slot retires, only tree pages remain
    for sid in sorted(slots):
        pool.decref(slots.pop(sid))
    check_serving_invariants(ctx())
    tp = tree.tree_pages()
    assert pool.used() == len(set(tp))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_control_plane_fuzz_seeded(seed):
    _fuzz_control_plane(seed)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_control_plane_fuzz_property(seed):
    _fuzz_control_plane(seed, steps=60)


def test_fuzz_checker_is_not_vacuous():
    """The fuzz harness's checker must actually be able to fail: hand it
    a deliberately leaked page and expect InvariantViolation."""
    pool = PagePool(4)
    tree = PrefixCache(pool, hot_cap=2, page_size=2)
    pool.alloc(1)  # born with a reader nobody registered -> leak
    ctx = SimpleNamespace(pool=pool, ptree=tree,
                          sched=SimpleNamespace(slot_req=[]),
                          slot_pages=[], host_table=None)
    with pytest.raises(InvariantViolation, match="leak"):
        check_serving_invariants(ctx)
